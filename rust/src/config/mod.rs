//! Run configuration: everything a training run needs, loadable from
//! JSON launcher files (`configs/*.json`) or built programmatically by
//! the experiment harness.  Serialization uses the in-repo JSON
//! substrate (`util::json`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::optim::LrSchedule;
use crate::util::fault::{self, FaultSiteCfg, FaultsCfg};
use crate::util::json::{parse, Json};

/// Which dataset backs the run.
#[derive(Debug, Clone)]
pub enum DataCfg {
    /// Procedural CIFAR-like generator (default on the offline testbed).
    Synthetic { classes: usize, n_train: usize, n_test: usize, seed: u64 },
    /// Real CIFAR-10 binaries, if present on disk.
    CifarBin { dir: PathBuf },
}

impl Default for DataCfg {
    fn default() -> Self {
        DataCfg::Synthetic { classes: 10, n_train: 2048, n_test: 512, seed: 0 }
    }
}

/// Explicit execution-backend selection for the step loop
/// (`runtime::exec::StepBackend`).  All three backends are bitwise
/// interchangeable for a fixed seed (tests/backend_matrix.rs) — this
/// knob picks *where* a step executes, never *what* it computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// Legacy host path: the full state converts in and out of the
    /// executing backend every step (the equivalence baseline).
    Host,
    /// Device-resident state across steps (the single-executor default).
    Resident,
    /// Data-parallel sharded execution over an engine pool
    /// (`runtime::shard`); requires `shards >= 1`.
    Sharded,
    /// Let the planner pick (`coordinator::planner`): backend, shard
    /// count and prefetch depth are chosen from the calibrated cost
    /// catalog at launch.  Accepts no explicit `shards` — the planner
    /// owns the whole layout.  Still outside the determinism
    /// fingerprint: whatever plan it picks is bitwise identical to the
    /// same layout requested explicitly (tests/planner_matrix.rs).
    Auto,
}

impl BackendChoice {
    pub fn as_str(self) -> &'static str {
        match self {
            BackendChoice::Host => "host",
            BackendChoice::Resident => "resident",
            BackendChoice::Sharded => "sharded",
            BackendChoice::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "host" => Ok(BackendChoice::Host),
            "resident" => Ok(BackendChoice::Resident),
            "sharded" => Ok(BackendChoice::Sharded),
            "auto" => Ok(BackendChoice::Auto),
            other => Err(anyhow!(
                "unknown backend '{other}' (known: host, resident, sharded, auto)"
            )),
        }
    }
}

/// SMD (Sec. 3.1): drop each mini-batch with probability `p`.
#[derive(Debug, Clone, Copy)]
pub struct SmdCfg {
    pub enabled: bool,
    pub p: f64,
}

impl Default for SmdCfg {
    fn default() -> Self {
        Self { enabled: false, p: 0.5 }
    }
}

/// Stochastic-depth baseline schedule [66]: linear-decay survival from 1
/// at the first block to `p_l` at the last.
#[derive(Debug, Clone, Copy)]
pub struct SdCfg {
    pub p_l: f64,
}

impl Default for SdCfg {
    fn default() -> Self {
        Self { p_l: 0.5 }
    }
}

/// Durable checkpointing (the `checkpoint` subsystem): write a
/// `ckpt/v1` file every `every` iterations into the registry at `dir`.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptCfg {
    /// Checkpoint every N iterations (0 = off).
    pub every: u64,
    /// Registry directory (created on demand).  Required when
    /// `every > 0`.
    pub dir: Option<PathBuf>,
    /// Retention: always keep the newest N checkpoints.
    pub keep_last: usize,
    /// Retention: additionally keep every checkpoint whose iteration is
    /// a multiple of M forever (0 = none).
    pub keep_every: u64,
    /// Evacuation target: replicate every published checkpoint to this
    /// remote registry root (another failure domain) via the background
    /// [`crate::checkpoint::Replicator`].  Retention never prunes an
    /// entry that has not landed there yet.
    pub replicate: Option<PathBuf>,
    /// Restore source of last resort: when the local registry at `dir`
    /// has nothing readable, the supervisor falls back to this replica
    /// root (fetch-and-verify through
    /// [`crate::checkpoint::RemoteRegistry`]).
    pub replica: Option<PathBuf>,
}

impl Default for CkptCfg {
    fn default() -> Self {
        Self {
            every: 0,
            dir: None,
            keep_last: 3,
            keep_every: 0,
            replicate: None,
            replica: None,
        }
    }
}

/// One training run.
#[derive(Debug, Clone)]
pub struct RunCfg {
    /// Artifact family (e.g. "resnet8-c10-tiny") under `artifacts/`.
    pub family: String,
    /// Method artifact: sgd32 | fixed8 | signsgd | psg | slu | sd |
    /// e2train | headft.
    pub method: String,
    pub iters: u64,
    pub seed: u64,
    pub lr: LrSchedule,
    pub data: DataCfg,
    pub smd: SmdCfg,
    pub sd: SdCfg,
    /// Evaluate every `eval_every` iterations (0 = only at the end).
    pub eval_every: u64,
    /// Enable SWA (used by PSG runs per Sec. 4.1).
    pub swa: bool,
    /// SLU FLOPs-regularizer weight (Eq. 1); runtime scalar input.
    pub alpha: f64,
    /// PSG adaptive-threshold ratio (Sec. 3.3); runtime scalar input.
    pub beta: f64,
    /// Keep model state in device-resident buffers across steps (the
    /// default).  `false` forces the legacy host path — every step
    /// round-trips the full state through host tensors; kept for the
    /// equivalence tests and perf baselines.
    pub resident: bool,
    /// Assemble/augment batches on a background thread (double-buffered).
    /// `false` samples synchronously inside the step loop.
    pub prefetch: bool,
    /// Data-parallel shard count.  `0` (the default) runs the
    /// single-executor resident/host path; `N >= 1` splits every batch
    /// across N engines with a deterministic host-side all-reduce
    /// (`runtime::shard` — reference-backend families only; `N = 1`
    /// exercises the sharded machinery on one engine).  When set, it
    /// supersedes `resident` for the step loop.
    pub shards: usize,
    /// Explicit execution-backend selection.  `None` (the default)
    /// keeps the legacy mapping — `shards >= 1` selects sharded, else
    /// `resident` selects resident vs host; `Some(..)` names the
    /// backend outright and is validated against `shards`
    /// ([`RunCfg::validate_backend`]).  Not part of the determinism
    /// fingerprint: backends are bitwise interchangeable, so a
    /// checkpoint taken under one may resume under another.
    pub backend: Option<BackendChoice>,
    /// Gradient accumulation: micro-batches per logical step on the
    /// sharded backend (pipelined through the reducer thread,
    /// `runtime::shard`).  `1` (the default) reduces the whole batch in
    /// one job.  A pure layout knob — any value is bitwise identical to
    /// `1` (tests/reduce_matrix.rs) — so, like `shards`, it stays
    /// outside the determinism fingerprint.  Values > 1 require the
    /// resolved backend to be sharded ([`RunCfg::validate_backend`]).
    pub accum: usize,
    /// Durable checkpoint cadence + registry (`checkpoint` subsystem):
    /// when `checkpoint.every > 0`, the trainer publishes a `ckpt/v1`
    /// file at every boundary and `e2train resume <dir>` continues the
    /// run bitwise-identically (tests/resume_equivalence.rs).
    pub checkpoint: CkptCfg,
    /// Fault injection + supervised recovery policy
    /// (`util::fault` / `coordinator::supervisor`): armed sites inject
    /// deterministic failures, and `max_retries`/`backoff_ms` bound the
    /// supervisor's restore-and-resume loop.  Not part of the
    /// determinism fingerprint — a recovered run is bitwise identical
    /// to the fault-free run (tests/fault_matrix.rs), so it must
    /// fingerprint identically too.
    pub faults: FaultsCfg,
    /// Observability (`obs` subsystem): when set, the trainer writes an
    /// `obs_trace/v1` JSONL event log here at the end of the run.  Not
    /// part of the determinism fingerprint — telemetry is provably
    /// inert (tests/obs_invariance.rs): a traced run is bitwise
    /// identical to an untraced one, so where (or whether) the trace
    /// lands cannot change the training stream.
    pub trace_out: Option<PathBuf>,
    /// Planner energy hint (`backend = "auto"` only): prefer the fastest
    /// plan whose predicted total joules fit this budget; when none fit,
    /// take the lowest-energy plan.  A *plan-selection* hint, not a
    /// controller — the run itself is unchanged, so it stays outside the
    /// determinism fingerprint.
    pub energy_budget_j: Option<f64>,
    /// Cost-catalog file (`obs_catalog/v1`) the planner reads and every
    /// run recalibrates.  Defaults to `OBS_CATALOG.json` (next to the
    /// BENCH reports) when `backend = "auto"`; explicit-backend runs
    /// only touch the catalog when this is set.  Pure layout/telemetry
    /// plumbing — outside the determinism fingerprint.
    pub catalog: Option<PathBuf>,
    pub artifacts_dir: PathBuf,
}

impl RunCfg {
    /// Sensible defaults for a quick run of (family, method).
    pub fn quick(family: &str, method: &str, iters: u64) -> Self {
        let lr0 = match method {
            // SignSGD-family methods want small lr (Sec. 4.1 / appendix B).
            "signsgd" | "psg" | "e2train" => 0.03,
            _ => 0.1,
        };
        RunCfg {
            family: family.to_string(),
            method: method.to_string(),
            iters,
            seed: 0,
            lr: LrSchedule::paper_default(lr0, iters),
            data: DataCfg::default(),
            smd: SmdCfg { enabled: matches!(method, "e2train"), p: 0.5 },
            sd: SdCfg::default(),
            eval_every: 0,
            swa: matches!(method, "psg" | "e2train"),
            alpha: 1.0,
            beta: 0.05,
            resident: true,
            prefetch: true,
            shards: 0,
            backend: None,
            accum: 1,
            checkpoint: CkptCfg::default(),
            faults: FaultsCfg::default(),
            trace_out: None,
            energy_budget_j: None,
            catalog: None,
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }

    /// The execution backend this config selects: the explicit
    /// `backend` knob when present, else the legacy mapping from
    /// `shards` / `resident`.
    pub fn resolved_backend(&self) -> BackendChoice {
        match self.backend {
            Some(b) => b,
            None if self.shards >= 1 => BackendChoice::Sharded,
            None if self.resident => BackendChoice::Resident,
            None => BackendChoice::Host,
        }
    }

    /// Reject contradictory backend/shards/accum combinations.  Called
    /// by the JSON parser *and* by `Trainer::new`, so launcher files and
    /// programmatic configs fail with the same clean message instead of
    /// one knob silently superseding the other.
    pub fn validate_backend(&self) -> Result<()> {
        if self.accum == 0 {
            return Err(anyhow!(
                "accum must be >= 1 (micro-batches per training step)"
            ));
        }
        if self.accum > 1 && self.resolved_backend() != BackendChoice::Sharded {
            return Err(anyhow!(
                "accum = {} requires the sharded backend (gradient \
                 accumulation is a sharded-training knob; set backend \
                 \"sharded\" + `shards`, or drop `accum`)",
                self.accum
            ));
        }
        match self.backend {
            Some(BackendChoice::Sharded) if self.shards == 0 => Err(anyhow!(
                "backend \"sharded\" needs shards >= 1 (set the `shards` knob)"
            )),
            Some(BackendChoice::Auto) if self.shards >= 1 => Err(anyhow!(
                "backend \"auto\" accepts no explicit shards (the planner \
                 chooses the shard count; drop `shards` = {})",
                self.shards
            )),
            Some(b @ (BackendChoice::Host | BackendChoice::Resident))
                if self.shards >= 1 =>
            {
                Err(anyhow!(
                    "backend \"{}\" contradicts shards = {} (drop `shards` or \
                     select backend \"sharded\")",
                    b.as_str(),
                    self.shards
                ))
            }
            _ => Ok(()),
        }
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.artifacts_dir
            .join(&self.family)
            .join(format!("{}.json", self.method))
    }

    // ---------------- JSON (de)serialization ----------------

    fn lr_json(&self) -> Json {
        match &self.lr {
            LrSchedule::Constant { lr0 } => Json::obj(vec![
                ("kind", Json::str("constant")),
                ("lr0", Json::num(*lr0)),
            ]),
            LrSchedule::Step { lr0, decay, boundaries } => Json::obj(vec![
                ("kind", Json::str("step")),
                ("lr0", Json::num(*lr0)),
                ("decay", Json::num(*decay)),
                (
                    "boundaries",
                    Json::arr(boundaries.iter().map(|&b| Json::num(b as f64))),
                ),
            ]),
        }
    }

    fn data_json(&self) -> Json {
        match &self.data {
            DataCfg::Synthetic { classes, n_train, n_test, seed } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("classes", Json::num(*classes as f64)),
                ("n_train", Json::num(*n_train as f64)),
                ("n_test", Json::num(*n_test as f64)),
                ("seed", Json::num(*seed as f64)),
            ]),
            DataCfg::CifarBin { dir } => Json::obj(vec![
                ("kind", Json::str("cifar_bin")),
                ("dir", Json::str(dir.to_string_lossy())),
            ]),
        }
    }

    fn smd_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.smd.enabled)),
            ("p", Json::num(self.smd.p)),
        ])
    }

    fn sd_json(&self) -> Json {
        Json::obj(vec![("p_l", Json::num(self.sd.p_l))])
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("family", Json::str(&self.family)),
            ("method", Json::str(&self.method)),
            ("iters", Json::num(self.iters as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", self.lr_json()),
            ("data", self.data_json()),
            ("smd", self.smd_json()),
            ("sd", self.sd_json()),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("swa", Json::Bool(self.swa)),
            ("alpha", Json::num(self.alpha)),
            ("beta", Json::num(self.beta)),
            ("resident", Json::Bool(self.resident)),
            ("prefetch", Json::Bool(self.prefetch)),
            ("shards", Json::num(self.shards as f64)),
            (
                "backend",
                match self.backend {
                    Some(b) => Json::str(b.as_str()),
                    None => Json::Null,
                },
            ),
            ("accum", Json::num(self.accum as f64)),
            (
                "checkpoint",
                Json::obj(vec![
                    ("every", Json::num(self.checkpoint.every as f64)),
                    (
                        "dir",
                        match &self.checkpoint.dir {
                            Some(d) => Json::str(d.to_string_lossy()),
                            None => Json::Null,
                        },
                    ),
                    ("keep_last", Json::num(self.checkpoint.keep_last as f64)),
                    ("keep_every", Json::num(self.checkpoint.keep_every as f64)),
                    (
                        "replicate",
                        match &self.checkpoint.replicate {
                            Some(d) => Json::str(d.to_string_lossy()),
                            None => Json::Null,
                        },
                    ),
                    (
                        "replica",
                        match &self.checkpoint.replica {
                            Some(d) => Json::str(d.to_string_lossy()),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            (
                "faults",
                Json::obj(vec![
                    ("seed", Json::num(self.faults.seed as f64)),
                    ("max_retries", Json::num(self.faults.max_retries as f64)),
                    ("backoff_ms", Json::num(self.faults.backoff_ms as f64)),
                    (
                        "sites",
                        Json::arr(self.faults.sites.iter().map(|s| {
                            let mut kv = vec![
                                ("site", Json::str(&s.site)),
                                ("at", Json::num(s.at as f64)),
                                ("times", Json::num(s.times as f64)),
                            ];
                            if let Some(b) = s.after_bytes {
                                kv.push(("after_bytes", Json::num(b as f64)));
                            }
                            Json::obj(kv)
                        })),
                    ),
                ]),
            ),
            (
                "trace_out",
                match &self.trace_out {
                    Some(p) => Json::str(p.to_string_lossy()),
                    None => Json::Null,
                },
            ),
            (
                "energy_budget_j",
                match self.energy_budget_j {
                    Some(j) => Json::num(j),
                    None => Json::Null,
                },
            ),
            (
                "catalog",
                match &self.catalog {
                    Some(p) => Json::str(p.to_string_lossy()),
                    None => Json::Null,
                },
            ),
            (
                "artifacts_dir",
                Json::str(self.artifacts_dir.to_string_lossy()),
            ),
        ])
    }

    /// JSON of exactly the fields the bitwise-resume contract depends
    /// on.  Execution-layout knobs (`backend` / `resident` / `prefetch`
    /// / `shards` / `accum`) are deliberately **excluded**: the backends are
    /// bitwise interchangeable (tests/backend_matrix.rs,
    /// tests/{resident,shard}_equivalence.rs), so a checkpoint written
    /// by a resident run may legally resume sharded and vice versa.  Paths and checkpoint cadence are excluded too —
    /// relocating artifacts (`resume --artifacts`) or the CIFAR
    /// binaries (`resume --data-dir`) or re-checkpointing on a
    /// different schedule does not change the training stream.
    /// `trace_out` is likewise excluded: telemetry is inert
    /// (tests/obs_invariance.rs), so tracing a run must not move its
    /// fingerprint.
    pub fn determinism_json(&self) -> Json {
        // The CIFAR `dir` is a mount point, not an identity: a
        // preempted edge run must stay resumable after its storage
        // comes back at a different path.  The synthetic generator's
        // parameters *are* its identity and stay in.
        let data = match &self.data {
            DataCfg::Synthetic { .. } => self.data_json(),
            DataCfg::CifarBin { .. } => {
                Json::obj(vec![("kind", Json::str("cifar_bin"))])
            }
        };
        Json::obj(vec![
            ("family", Json::str(&self.family)),
            ("method", Json::str(&self.method)),
            ("iters", Json::num(self.iters as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", self.lr_json()),
            ("data", data),
            ("smd", self.smd_json()),
            ("sd", self.sd_json()),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("swa", Json::Bool(self.swa)),
            ("alpha", Json::num(self.alpha)),
            ("beta", Json::num(self.beta)),
        ])
    }

    /// FNV-1a-64 hex fingerprint of [`RunCfg::determinism_json`] —
    /// stamped into every checkpoint and verified on resume.
    pub fn fingerprint(&self) -> String {
        crate::util::hash::fnv1a64_hex(self.determinism_json().to_string().as_bytes())
    }

    /// Reject object keys this version does not understand — catches
    /// launcher-file drift (a typo'd or stale knob silently falling back
    /// to its default is exactly how a "checkpointed" run ends up never
    /// checkpointing).  Keys starting with `_` are comments and pass
    /// (`"_comment"` in the shipped launchers).
    fn check_keys(v: &Json, allowed: &[&str], ctx: &str) -> Result<()> {
        if let Some(m) = v.as_obj() {
            for k in m.keys() {
                if !k.starts_with('_') && !allowed.contains(&k.as_str()) {
                    return Err(anyhow!(
                        "unknown {ctx} key '{k}' (known keys: {})",
                        allowed.join(", ")
                    ));
                }
            }
        }
        Ok(())
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Self::check_keys(
            v,
            &[
                "family", "method", "iters", "seed", "lr", "data", "smd", "sd",
                "eval_every", "swa", "alpha", "beta", "resident", "prefetch",
                "shards", "backend", "accum", "checkpoint", "faults",
                "trace_out", "energy_budget_j", "catalog", "artifacts_dir",
            ],
            "run-config",
        )?;
        let family = v.req_str("family")?.to_string();
        let method = v.req_str("method")?.to_string();
        let iters = v.req_f64("iters")? as u64;
        let mut cfg = RunCfg::quick(&family, &method, iters);
        cfg.seed = v.get("seed").and_then(Json::as_u64).unwrap_or(0);
        if let Some(lr) = v.get("lr") {
            // Per-kind allowlists: a knob belonging to the *other*
            // variant is exactly as dead as a typo'd one.
            cfg.lr = match lr.req_str("kind")? {
                "constant" => {
                    Self::check_keys(lr, &["kind", "lr0"], "lr(constant)")?;
                    LrSchedule::Constant { lr0: lr.req_f64("lr0")? }
                }
                "step" => {
                    Self::check_keys(
                        lr,
                        &["kind", "lr0", "decay", "boundaries"],
                        "lr(step)",
                    )?;
                    LrSchedule::Step {
                        lr0: lr.req_f64("lr0")?,
                        decay: lr.req_f64("decay")?,
                        boundaries: lr
                            .req_arr("boundaries")?
                            .iter()
                            .filter_map(Json::as_u64)
                            .collect(),
                    }
                }
                other => return Err(anyhow!("unknown lr kind {other}")),
            };
        }
        if let Some(d) = v.get("data") {
            cfg.data = match d.req_str("kind")? {
                "synthetic" => {
                    Self::check_keys(
                        d,
                        &["kind", "classes", "n_train", "n_test", "seed"],
                        "data(synthetic)",
                    )?;
                    DataCfg::Synthetic {
                        classes: d.req_f64("classes")? as usize,
                        n_train: d.req_f64("n_train")? as usize,
                        n_test: d.req_f64("n_test")? as usize,
                        seed: d.get("seed").and_then(Json::as_u64).unwrap_or(0),
                    }
                }
                "cifar_bin" => {
                    Self::check_keys(d, &["kind", "dir"], "data(cifar_bin)")?;
                    DataCfg::CifarBin { dir: PathBuf::from(d.req_str("dir")?) }
                }
                other => return Err(anyhow!("unknown data kind {other}")),
            };
        }
        if let Some(s) = v.get("smd") {
            Self::check_keys(s, &["enabled", "p"], "smd")?;
            cfg.smd = SmdCfg {
                enabled: s.get("enabled").and_then(Json::as_bool).unwrap_or(false),
                p: s.get("p").and_then(Json::as_f64).unwrap_or(0.5),
            };
        }
        if let Some(s) = v.get("sd") {
            Self::check_keys(s, &["p_l"], "sd")?;
            cfg.sd = SdCfg { p_l: s.get("p_l").and_then(Json::as_f64).unwrap_or(0.5) };
        }
        cfg.eval_every = v.get("eval_every").and_then(Json::as_u64).unwrap_or(0);
        cfg.swa = v.get("swa").and_then(Json::as_bool).unwrap_or(cfg.swa);
        cfg.alpha = v.get("alpha").and_then(Json::as_f64).unwrap_or(1.0);
        cfg.beta = v.get("beta").and_then(Json::as_f64).unwrap_or(0.05);
        cfg.resident = v.get("resident").and_then(Json::as_bool).unwrap_or(true);
        cfg.prefetch = v.get("prefetch").and_then(Json::as_bool).unwrap_or(true);
        cfg.shards = v.get("shards").and_then(Json::as_usize).unwrap_or(0);
        cfg.backend = match v.get("backend") {
            None | Some(Json::Null) => None,
            Some(b) => Some(BackendChoice::parse(b.as_str().ok_or_else(|| {
                anyhow!("`backend` must be a string (host | resident | sharded | auto)")
            })?)?),
        };
        cfg.accum = match v.get("accum") {
            None | Some(Json::Null) => 1,
            Some(a) => a
                .as_usize()
                .ok_or_else(|| anyhow!("`accum` must be a non-negative integer"))?,
        };
        cfg.validate_backend()?;
        cfg.energy_budget_j = match v.get("energy_budget_j") {
            None | Some(Json::Null) => None,
            Some(j) => Some(
                j.as_f64()
                    .filter(|j| j.is_finite() && *j > 0.0)
                    .ok_or_else(|| {
                        anyhow!("`energy_budget_j` must be a positive number of joules")
                    })?,
            ),
        };
        if cfg.energy_budget_j.is_some() && cfg.backend != Some(BackendChoice::Auto) {
            return Err(anyhow!(
                "`energy_budget_j` is a planner hint — it requires backend \"auto\""
            ));
        }
        cfg.catalog = v.get("catalog").and_then(Json::as_str).map(PathBuf::from);
        if let Some(c) = v.get("checkpoint") {
            Self::check_keys(
                c,
                &["every", "dir", "keep_last", "keep_every", "replicate", "replica"],
                "checkpoint",
            )?;
            cfg.checkpoint = CkptCfg {
                every: c.get("every").and_then(Json::as_u64).unwrap_or(0),
                dir: c.get("dir").and_then(Json::as_str).map(PathBuf::from),
                keep_last: c.get("keep_last").and_then(Json::as_usize).unwrap_or(3),
                keep_every: c.get("keep_every").and_then(Json::as_u64).unwrap_or(0),
                replicate: c.get("replicate").and_then(Json::as_str).map(PathBuf::from),
                replica: c.get("replica").and_then(Json::as_str).map(PathBuf::from),
            };
            if cfg.checkpoint.replicate.is_some() && cfg.checkpoint.every == 0 {
                return Err(anyhow!(
                    "checkpoint.replicate is set but checkpoint.every = 0 \
                     (nothing will ever be published to evacuate)"
                ));
            }
            if cfg.checkpoint.every > 0 && cfg.checkpoint.dir.is_none() {
                return Err(anyhow!(
                    "checkpoint.every = {} but checkpoint.dir is unset",
                    cfg.checkpoint.every
                ));
            }
        }
        if let Some(f) = v.get("faults") {
            Self::check_keys(
                f,
                &["seed", "max_retries", "backoff_ms", "sites"],
                "faults",
            )?;
            let mut faults = FaultsCfg {
                seed: f.get("seed").and_then(Json::as_u64).unwrap_or(0),
                ..FaultsCfg::default()
            };
            if let Some(r) = f.get("max_retries").and_then(Json::as_u64) {
                faults.max_retries = r;
            }
            if let Some(b) = f.get("backoff_ms").and_then(Json::as_u64) {
                faults.backoff_ms = b;
            }
            if f.get("sites").is_some() {
                for s in f.req_arr("sites")? {
                    Self::check_keys(
                        s,
                        &["site", "at", "times", "after_bytes"],
                        "faults.sites entry",
                    )?;
                    let site = s.req_str("site")?.to_string();
                    if !fault::KNOWN_SITES.contains(&site.as_str()) {
                        return Err(anyhow!(
                            "unknown fault site '{site}' (known sites: {})",
                            fault::KNOWN_SITES.join(", ")
                        ));
                    }
                    faults.sites.push(FaultSiteCfg {
                        site,
                        at: s.get("at").and_then(Json::as_u64).unwrap_or(0),
                        times: s.get("times").and_then(Json::as_u64).unwrap_or(1),
                        after_bytes: s.get("after_bytes").and_then(Json::as_u64),
                    });
                }
            }
            cfg.faults = faults;
        }
        cfg.trace_out = v.get("trace_out").and_then(Json::as_str).map(PathBuf::from);
        if let Some(d) = v.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(d);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&parse(&text)?)
            .with_context(|| format!("parsing run config {}", path.display()))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn json_roundtrip() {
        let mut cfg = RunCfg::quick("resnet8-c10-tiny", "e2train", 100);
        cfg.alpha = 2.5;
        cfg.eval_every = 10;
        cfg.resident = false;
        cfg.prefetch = false;
        cfg.shards = 2;
        cfg.accum = 2;
        cfg.checkpoint = CkptCfg {
            every: 25,
            dir: Some(PathBuf::from("ckpts/run1")),
            keep_last: 2,
            keep_every: 50,
            replicate: Some(PathBuf::from("replica/run1")),
            replica: Some(PathBuf::from("replica/run1")),
        };
        cfg.faults = FaultsCfg {
            sites: vec![
                FaultSiteCfg {
                    site: fault::SITE_TRAIN_STEP.into(),
                    at: 7,
                    times: 2,
                    after_bytes: None,
                },
                FaultSiteCfg {
                    site: fault::SITE_CKPT_SINK.into(),
                    at: 0,
                    times: 1,
                    after_bytes: Some(4096),
                },
            ],
            max_retries: 6,
            backoff_ms: 3,
            seed: 11,
        };
        cfg.trace_out = Some(PathBuf::from("out/trace.jsonl"));
        let dir = TempDir::new().unwrap();
        let p = dir.path().join("run.json");
        cfg.save(&p).unwrap();
        let back = RunCfg::load(&p).unwrap();
        assert_eq!(back.family, cfg.family);
        assert_eq!(back.method, "e2train");
        assert!(back.smd.enabled);
        assert!(back.swa);
        assert_eq!(back.alpha, 2.5);
        assert_eq!(back.eval_every, 10);
        assert_eq!(back.lr, cfg.lr);
        assert!(!back.resident && !back.prefetch);
        assert_eq!(back.shards, 2);
        assert_eq!(back.accum, 2);
        assert_eq!(back.checkpoint, cfg.checkpoint);
        assert_eq!(back.faults, cfg.faults);
        assert_eq!(back.trace_out, cfg.trace_out);
    }

    #[test]
    fn fault_section_is_strictly_validated() {
        let base = RunCfg::quick("f", "sgd32", 5).to_json();
        // an unknown site name is a config error, not a silent no-op
        let mut m = base.as_obj().unwrap().clone();
        m.insert(
            "faults".into(),
            Json::obj(vec![(
                "sites",
                Json::arr([Json::obj(vec![("site", Json::str("disk.melt"))])]),
            )]),
        );
        let err = format!("{:#}", RunCfg::from_json(&Json::Obj(m)).unwrap_err());
        assert!(err.contains("disk.melt"), "unexpected error: {err}");
        // ...and so is a typo'd policy knob
        let mut m = base.as_obj().unwrap().clone();
        m.insert(
            "faults".into(),
            Json::obj(vec![("max_retrys", Json::num(2.0))]),
        );
        let err = format!("{:#}", RunCfg::from_json(&Json::Obj(m)).unwrap_err());
        assert!(err.contains("max_retrys"), "unexpected error: {err}");
        // ...or a stale per-site key
        let mut m = base.as_obj().unwrap().clone();
        m.insert(
            "faults".into(),
            Json::obj(vec![(
                "sites",
                Json::arr([Json::obj(vec![
                    ("site", Json::str(fault::SITE_PREFETCH)),
                    ("when", Json::num(3.0)),
                ])]),
            )]),
        );
        let err = format!("{:#}", RunCfg::from_json(&Json::Obj(m)).unwrap_err());
        assert!(err.contains("when"), "unexpected error: {err}");
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let base = RunCfg::quick("f", "sgd32", 5).to_json();
        // a stale/typo'd top-level knob must not silently no-op
        let mut m = base.as_obj().unwrap().clone();
        m.insert("checkpoint_evry".into(), Json::num(10.0));
        let err = RunCfg::from_json(&Json::Obj(m)).unwrap_err();
        assert!(format!("{err:#}").contains("checkpoint_evry"));
        // ...nor a nested one
        let mut m = base.as_obj().unwrap().clone();
        m.insert(
            "checkpoint".into(),
            Json::obj(vec![("evry", Json::num(10.0))]),
        );
        assert!(RunCfg::from_json(&Json::Obj(m)).is_err());
        // checkpointing without a registry dir is a config error
        let mut m = base.as_obj().unwrap().clone();
        m.insert(
            "checkpoint".into(),
            Json::obj(vec![("every", Json::num(10.0))]),
        );
        let err = RunCfg::from_json(&Json::Obj(m)).unwrap_err();
        assert!(format!("{err:#}").contains("checkpoint.dir"));
    }

    #[test]
    fn fingerprint_tracks_determinism_fields_only() {
        let a = RunCfg::quick("f", "e2train", 100);
        // layout knobs don't change the fingerprint...
        let mut b = a.clone();
        b.resident = false;
        b.prefetch = false;
        b.shards = 3;
        b.backend = Some(BackendChoice::Sharded);
        b.accum = 4;
        b.artifacts_dir = PathBuf::from("elsewhere");
        b.checkpoint.every = 7;
        b.checkpoint.dir = Some(PathBuf::from("x"));
        b.trace_out = Some(PathBuf::from("trace.jsonl"));
        // planner knobs are layout/selection hints, not stream identity
        b.energy_budget_j = Some(125.0);
        b.catalog = Some(PathBuf::from("OBS_CATALOG.json"));
        // ...and neither does an armed fault plan: a supervised run that
        // recovers from injected faults must fingerprint-match both its
        // own checkpoints and the fault-free baseline.
        b.faults.sites.push(FaultSiteCfg {
            site: fault::SITE_TRAIN_STEP.into(),
            at: 3,
            times: 1,
            after_bytes: None,
        });
        b.faults.max_retries = 9;
        assert_eq!(a.fingerprint(), b.fingerprint());
        // ...stream-relevant knobs do
        let mut c = a.clone();
        c.seed = 1;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = a.clone();
        d.smd.p = 0.25;
        assert_ne!(a.fingerprint(), d.fingerprint());
        let mut e = a.clone();
        e.iters = 101;
        assert_ne!(a.fingerprint(), e.fingerprint());
        // CIFAR mount point is relocatable; synthetic params are not
        let mut f = a.clone();
        f.data = DataCfg::CifarBin { dir: PathBuf::from("/mnt/sd/cifar") };
        let mut g = f.clone();
        g.data = DataCfg::CifarBin { dir: PathBuf::from("/data/cifar") };
        assert_eq!(f.fingerprint(), g.fingerprint());
        assert_ne!(a.fingerprint(), f.fingerprint());
        let mut h = a.clone();
        h.data = DataCfg::Synthetic { classes: 10, n_train: 999, n_test: 512, seed: 0 };
        assert_ne!(a.fingerprint(), h.fingerprint());
    }

    #[test]
    fn backend_knob_resolves_and_validates() {
        // Legacy mapping when the knob is absent.
        let mut cfg = RunCfg::quick("f", "sgd32", 5);
        assert_eq!(cfg.resolved_backend(), BackendChoice::Resident);
        cfg.resident = false;
        assert_eq!(cfg.resolved_backend(), BackendChoice::Host);
        cfg.shards = 2;
        assert_eq!(cfg.resolved_backend(), BackendChoice::Sharded);

        // Explicit knob wins, and round-trips through JSON.
        let mut cfg = RunCfg::quick("f", "sgd32", 5);
        cfg.backend = Some(BackendChoice::Sharded);
        cfg.shards = 2;
        cfg.validate_backend().unwrap();
        let back = RunCfg::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.backend, Some(BackendChoice::Sharded));
        assert_eq!(back.resolved_backend(), BackendChoice::Sharded);

        // Contradictions are rejected, programmatically and via JSON.
        let mut bad = RunCfg::quick("f", "sgd32", 5);
        bad.backend = Some(BackendChoice::Sharded);
        assert!(bad.validate_backend().is_err(), "sharded without shards");
        let mut bad = RunCfg::quick("f", "sgd32", 5);
        bad.backend = Some(BackendChoice::Host);
        bad.shards = 2;
        let err = format!("{:#}", bad.validate_backend().unwrap_err());
        assert!(err.contains("host") && err.contains("shards"));
        assert!(RunCfg::from_json(&bad.to_json()).is_err());

        // Unknown spelling fails the parse with a naming message.
        let mut m = RunCfg::quick("f", "sgd32", 5).to_json().as_obj().unwrap().clone();
        m.insert("backend".into(), Json::str("warp"));
        let err = format!("{:#}", RunCfg::from_json(&Json::Obj(m)).unwrap_err());
        assert!(err.contains("warp"));
    }

    #[test]
    fn accum_knob_validates_and_roundtrips() {
        // Valid: sharded + accum > 1, round-trips through JSON.
        let mut cfg = RunCfg::quick("f", "sgd32", 5);
        cfg.backend = Some(BackendChoice::Sharded);
        cfg.shards = 4;
        cfg.accum = 4;
        cfg.validate_backend().unwrap();
        let back = RunCfg::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.accum, 4);

        // accum = 0 is rejected, programmatically and via JSON.
        let mut bad = cfg.clone();
        bad.accum = 0;
        let err = format!("{:#}", bad.validate_backend().unwrap_err());
        assert!(err.contains(">= 1"), "{err}");
        assert!(RunCfg::from_json(&bad.to_json()).is_err());

        // accum > 1 without the sharded backend is rejected...
        let mut bad = RunCfg::quick("f", "sgd32", 5);
        bad.accum = 2;
        let err = format!("{:#}", bad.validate_backend().unwrap_err());
        assert!(err.contains("sharded"), "{err}");
        assert!(RunCfg::from_json(&bad.to_json()).is_err());
        // ...including "auto" (the planner may pick a single-executor
        // layout, which would silently drop the knob).
        let mut bad = RunCfg::quick("f", "sgd32", 5);
        bad.backend = Some(BackendChoice::Auto);
        bad.accum = 2;
        assert!(bad.validate_backend().is_err());

        // Absent knob defaults to 1 (single micro-batch).
        assert_eq!(RunCfg::quick("f", "sgd32", 5).accum, 1);
        // The legacy shards-only mapping accepts accum too.
        let mut legacy = RunCfg::quick("f", "sgd32", 5);
        legacy.shards = 2;
        legacy.accum = 3;
        legacy.validate_backend().unwrap();
    }

    #[test]
    fn auto_backend_and_planner_knobs_validate() {
        // "auto" parses, round-trips, and resolves to itself (the
        // planner replaces it before any backend is prepared).
        let mut cfg = RunCfg::quick("f", "sgd32", 5);
        cfg.backend = Some(BackendChoice::Auto);
        cfg.energy_budget_j = Some(42.5);
        cfg.catalog = Some(PathBuf::from("cat.json"));
        cfg.validate_backend().unwrap();
        assert_eq!(cfg.resolved_backend(), BackendChoice::Auto);
        let back = RunCfg::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.backend, Some(BackendChoice::Auto));
        assert_eq!(back.energy_budget_j, Some(42.5));
        assert_eq!(back.catalog, Some(PathBuf::from("cat.json")));

        // auto + explicit shards contradict: the planner owns the layout.
        let mut bad = RunCfg::quick("f", "sgd32", 5);
        bad.backend = Some(BackendChoice::Auto);
        bad.shards = 2;
        let err = format!("{:#}", bad.validate_backend().unwrap_err());
        assert!(err.contains("auto") && err.contains("shards"), "{err}");
        assert!(RunCfg::from_json(&bad.to_json()).is_err());

        // the energy budget is meaningless without the planner
        let mut bad = RunCfg::quick("f", "sgd32", 5);
        bad.energy_budget_j = Some(10.0);
        let err = format!("{:#}", RunCfg::from_json(&bad.to_json()).unwrap_err());
        assert!(err.contains("auto"), "{err}");
        // ...and must be a positive number
        let mut m = cfg.to_json().as_obj().unwrap().clone();
        m.insert("energy_budget_j".into(), Json::num(-3.0));
        assert!(RunCfg::from_json(&Json::Obj(m)).is_err());
        let mut m = cfg.to_json().as_obj().unwrap().clone();
        m.insert("energy_budget_j".into(), Json::str("lots"));
        assert!(RunCfg::from_json(&Json::Obj(m)).is_err());
    }

    #[test]
    fn quick_lr_defaults() {
        assert_eq!(RunCfg::quick("f", "sgd32", 10).lr.at(0), 0.1);
        assert_eq!(RunCfg::quick("f", "psg", 10).lr.at(0), 0.03);
    }

    #[test]
    fn manifest_path_layout() {
        let cfg = RunCfg::quick("fam", "slu", 1);
        assert_eq!(cfg.manifest_path(), PathBuf::from("artifacts/fam/slu.json"));
    }

    #[test]
    fn cifar_data_roundtrip() {
        let mut cfg = RunCfg::quick("f", "sgd32", 5);
        cfg.data = DataCfg::CifarBin { dir: PathBuf::from("/data/cifar") };
        let v = cfg.to_json();
        let back = RunCfg::from_json(&v).unwrap();
        match back.data {
            DataCfg::CifarBin { dir } => assert_eq!(dir, PathBuf::from("/data/cifar")),
            _ => panic!("wrong data kind"),
        }
    }
}

#[cfg(test)]
mod launcher_tests {
    use super::*;

    /// Every shipped launcher file in configs/ must parse.
    #[test]
    fn shipped_launchers_parse() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        let mut seen = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            if p.extension().map(|e| e == "json").unwrap_or(false) {
                let cfg = RunCfg::load(&p).unwrap();
                assert!(cfg.iters > 0, "{}", p.display());
                seen += 1;
            }
        }
        assert!(seen >= 3, "expected shipped launcher configs, found {seen}");
    }
}
