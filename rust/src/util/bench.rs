//! Micro-bench harness (no `criterion` on the offline testbed): warmup +
//! timed iterations, reporting mean/p50/p95 with simple outlier-robust
//! statistics.  Used by `benches/*.rs` (harness = false).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "{:<40} {:>6} iters  mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}",
            self.name,
            self.iters,
            fmt_t(self.mean_s),
            fmt_t(self.p50_s),
            fmt_t(self.p95_s),
            fmt_t(self.min_s)
        );
    }
}

pub fn fmt_t(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Run `f` with `warmup` unmeasured + `iters` measured repetitions.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_s: samples[samples.len() / 2],
        p95_s: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_s: samples[0],
    };
    stats.report();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let s = bench("busy", 1, 5, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_s >= 0.0 && s.min_s <= s.p95_s);
        assert!(acc > 0);
    }

    #[test]
    fn formats() {
        assert!(fmt_t(2e-9).ends_with("ns"));
        assert!(fmt_t(2e-6).ends_with("µs"));
        assert!(fmt_t(2e-3).ends_with("ms"));
        assert!(fmt_t(2.0).ends_with('s'));
    }
}
