//! Host-path vs resident-path perf comparison harness, shared by
//! `benches/bench_runtime.rs` (release numbers, the canonical record)
//! and the tier-1 smoke test (debug numbers, so `BENCH_runtime.json`
//! materializes on every verified checkout).  See PERF.md for how to
//! read the output.

use std::path::Path;

use anyhow::Result;

use crate::config::{DataCfg, RunCfg};
use crate::coordinator::Trainer;
use crate::data::{synthetic, AugmentCfg, Sampler};
use crate::runtime::{Engine, ModelState, StepHyper, TrainProgram};
use crate::util::bench::bench;
use crate::util::Json;

/// Per-method step-latency comparison: the same program driven through
/// the legacy host path and the resident path.
#[derive(Debug, Clone)]
pub struct StepComparison {
    pub method: String,
    pub host_mean_s: f64,
    pub resident_mean_s: f64,
}

impl StepComparison {
    /// host/resident — > 1.0 means the resident path is faster.
    pub fn speedup(&self) -> f64 {
        self.host_mean_s / self.resident_mean_s
    }
}

/// Trainer throughput with and without the prefetch pipeline (both on
/// the resident path).
#[derive(Debug, Clone)]
pub struct PrefetchComparison {
    pub steps_per_sec_on: f64,
    pub steps_per_sec_off: f64,
    /// Channel depth the auto-tuner chose for the prefetch-on run
    /// (`data::prefetch::auto_depth`, from the measured augment/step
    /// time ratio).
    pub chosen_depth: usize,
    /// Execution backend the trainer ran on (`RunMetrics::backend`) and
    /// its shard count — recorded into the report row so bench
    /// trajectories stay attributable across the `cfg.backend` knob.
    pub exec_backend: String,
    pub shards: usize,
    /// Wall-ms the prefetch-on run's consumer spent acquiring batches
    /// (`prefetch-stall` phase): near zero means the producer kept up.
    pub prefetch_stall_ms: f64,
    /// Mean prefetch-channel occupancy over the on-run's consumer
    /// samples (0 when never sampled): how full the pipeline ran.
    pub prefetch_occupancy: f64,
    /// Wall-ms the on-run's step loop blocked submitting checkpoints to
    /// the depth-1 writer queue (disk backpressure reaching the loop).
    /// Floored at 1 ns per submit, so read it against `ckpt_submits`:
    /// a value ≈ submits·1ns is clock/queue overhead, not backpressure.
    pub ckpt_backpressure_wait_ms: f64,
    /// Checkpoints the on-run submitted to the writer — the denominator
    /// that separates the wait field's per-submit floor from real
    /// backpressure.
    pub ckpt_submits: u64,
    /// Replication lag at run end: local iterations not yet evacuated
    /// to the replica (0 when replication was off or fully drained).
    pub replica_lag_iters: u64,
    /// Payload bytes the on-run's replicator landed on the remote store
    /// (0 when replication was off).
    pub replica_bytes: u64,
    /// Uploads that resumed from a prior attempt's verified staged
    /// bytes (0 when replication was off or never interrupted).
    pub replica_retries: u64,
}

/// Measure train-step latency through both state paths for one
/// (family, method) artifact.  Both paths execute the identical program
/// on identical inputs; only the state plumbing differs.
pub fn compare_step_paths(
    engine: &Engine,
    artifacts: &Path,
    family: &str,
    method: &str,
    warmup: usize,
    iters: usize,
) -> Result<StepComparison> {
    let prog = TrainProgram::load(
        engine,
        &artifacts.join(family).join(format!("{method}.json")),
    )?;
    let classes = prog.manifest.arch.num_classes;
    let hw = prog.manifest.arch.image_size;
    let data = synthetic::generate(classes, 256, hw, 0);
    let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 0);
    let (x, y) = sampler.next_batch(&data);
    let mask: Option<Vec<f32>> = (prog.manifest.method.gating == "mask")
        .then(|| vec![1.0; prog.manifest.num_gated()]);
    let hp = StepHyper::lr(0.05);

    let mut host_state = ModelState::init(&prog.manifest, 0);
    let host = bench(&format!("step/host/{family}/{method}"), warmup, iters, || {
        prog.step(&mut host_state, &x, &y, hp, mask.as_deref()).unwrap();
    });

    let mut dev_state = prog.upload_state(ModelState::init(&prog.manifest, 0))?;
    let resident = bench(
        &format!("step/resident/{family}/{method}"),
        warmup,
        iters,
        || {
            prog.step_device(&mut dev_state, &x, &y, hp, mask.as_deref())
                .unwrap();
        },
    );

    Ok(StepComparison {
        method: method.to_string(),
        host_mean_s: host.mean_s,
        resident_mean_s: resident.mean_s,
    })
}

/// Measure end-to-end trainer throughput (steps/s) with the prefetch
/// worker on vs off, resident path both times.
pub fn compare_prefetch(
    engine: &Engine,
    artifacts: &Path,
    family: &str,
    method: &str,
    iters: u64,
) -> Result<PrefetchComparison> {
    let run = |prefetch: bool| -> Result<crate::metrics::RunMetrics> {
        let mut cfg = RunCfg::quick(family, method, iters);
        cfg.artifacts_dir = artifacts.to_path_buf();
        cfg.prefetch = prefetch;
        cfg.smd.enabled = false;
        // Checkpoint a few times per run so the writer path (and its
        // submit backpressure counter) is exercised by the same run the
        // report describes.
        cfg.checkpoint.every = (iters / 3).max(1);
        cfg.checkpoint.dir = Some(artifacts.join(format!(
            "_bench_ckpt_{}",
            if prefetch { "on" } else { "off" }
        )));
        let manifest = crate::runtime::Manifest::load(&cfg.manifest_path())?;
        cfg.data = DataCfg::Synthetic {
            classes: manifest.arch.num_classes,
            n_train: 512,
            n_test: manifest.arch.eval_batch,
            seed: 0,
        };
        let mut trainer = Trainer::new(engine, cfg)?;
        Ok(trainer.run(None)?.metrics)
    };
    let on = run(true)?;
    let off = run(false)?;
    let obs = on.obs.clone().unwrap_or_default();
    let occ_samples = obs.counter(crate::obs::CTR_PREFETCH_OCC_SAMPLES);
    Ok(PrefetchComparison {
        steps_per_sec_on: on.steps_run as f64 / on.wall_seconds.max(1e-9),
        steps_per_sec_off: off.steps_run as f64 / off.wall_seconds.max(1e-9),
        chosen_depth: on
            .prefetch_depth
            .unwrap_or(crate::data::prefetch::DEFAULT_DEPTH),
        exec_backend: on.backend,
        shards: on.shards,
        prefetch_stall_ms: obs.phase_total_ms(crate::obs::PHASE_PREFETCH_STALL),
        prefetch_occupancy: if occ_samples == 0 {
            0.0
        } else {
            obs.counter(crate::obs::CTR_PREFETCH_OCC_SUM) as f64 / occ_samples as f64
        },
        ckpt_backpressure_wait_ms: obs.counter(crate::obs::CTR_CKPT_BACKPRESSURE_WAIT_NS)
            as f64
            / 1e6,
        ckpt_submits: obs.counter(crate::obs::CTR_CKPT_SUBMITS),
        replica_lag_iters: on.replica_lag_iters,
        replica_bytes: on.replica_bytes,
        replica_retries: on.replica_retries,
    })
}

/// Serialize a bench report.  `source` names the producer + build
/// profile so release bench numbers are distinguishable from the debug
/// smoke run.
pub fn bench_report(
    source: &str,
    family: &str,
    steps: &[StepComparison],
    prefetch: &PrefetchComparison,
) -> Json {
    Json::obj(vec![
        ("schema", Json::str("bench_runtime/v1")),
        ("source", Json::str(source)),
        ("family", Json::str(family)),
        ("backend", Json::str("reference")),
        (
            "step_latency",
            Json::Obj(
                steps
                    .iter()
                    .map(|s| {
                        (
                            s.method.clone(),
                            Json::obj(vec![
                                ("host_mean_s", Json::num(s.host_mean_s)),
                                ("resident_mean_s", Json::num(s.resident_mean_s)),
                                ("speedup", Json::num(s.speedup())),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "steps_per_sec",
            Json::obj(vec![
                ("prefetch_on", Json::num(prefetch.steps_per_sec_on)),
                ("prefetch_off", Json::num(prefetch.steps_per_sec_off)),
            ]),
        ),
        (
            "prefetch_depth",
            Json::num(prefetch.chosen_depth as f64),
        ),
        // Active execution backend (RunMetrics::backend) + shard count,
        // so rows stay attributable after the `cfg.backend` knob.
        ("exec_backend", Json::str(&prefetch.exec_backend)),
        ("shards", Json::num(prefetch.shards as f64)),
        // Observability-plane aggregates from the prefetch-on run
        // (additive fields; schema stays bench_runtime/v1 — see PERF.md).
        ("prefetch_stall_ms", Json::num(prefetch.prefetch_stall_ms)),
        ("prefetch_occupancy", Json::num(prefetch.prefetch_occupancy)),
        (
            "ckpt_backpressure_wait_ms",
            Json::num(prefetch.ckpt_backpressure_wait_ms),
        ),
        ("ckpt_submits", Json::num(prefetch.ckpt_submits as f64)),
        // Replication-lag aggregates (zeros when replication is off) —
        // additive like the obs fields above.
        ("replica_lag_iters", Json::num(prefetch.replica_lag_iters as f64)),
        ("replica_bytes", Json::num(prefetch.replica_bytes as f64)),
        ("replica_retries", Json::num(prefetch.replica_retries as f64)),
    ])
}

/// Write the report where the perf trajectory is tracked across PRs.
pub fn write_bench_report(path: &Path, report: &Json) -> Result<()> {
    std::fs::write(path, report.to_string())?;
    eprintln!("bench report -> {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{write_reference_family, RefFamilySpec};
    use crate::util::tmp::TempDir;

    #[test]
    fn comparison_runs_and_serializes() {
        let tmp = TempDir::new().unwrap();
        write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let cmp =
            compare_step_paths(&engine, tmp.path(), "refmlp-tiny", "sgd32", 1, 3).unwrap();
        assert!(cmp.host_mean_s > 0.0 && cmp.resident_mean_s > 0.0);
        let pf = compare_prefetch(&engine, tmp.path(), "refmlp-tiny", "sgd32", 6).unwrap();
        assert!(pf.steps_per_sec_on > 0.0 && pf.steps_per_sec_off > 0.0);
        assert!(
            (crate::data::prefetch::DEFAULT_DEPTH..=crate::data::prefetch::MAX_DEPTH)
                .contains(&pf.chosen_depth)
        );
        assert_eq!(pf.exec_backend, "resident");
        assert_eq!(pf.shards, 0);
        // The on-run checkpointed and consumed through the prefetcher,
        // so its observability aggregates are live, not defaults.
        assert!(pf.prefetch_stall_ms > 0.0, "stall phase never recorded");
        assert!(pf.prefetch_occupancy >= 0.0);
        assert!(
            pf.ckpt_backpressure_wait_ms > 0.0,
            "ckpt submits never counted"
        );
        assert!(pf.ckpt_submits > 0, "ckpt writer never submitted");
        let report = bench_report("unit-test", "refmlp-tiny", &[cmp], &pf);
        let text = report.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.at(&["schema"]).as_str(), Some("bench_runtime/v1"));
        assert!(back
            .at(&["step_latency", "sgd32", "speedup"])
            .as_f64()
            .is_some());
        assert!(back.at(&["prefetch_depth"]).as_f64().is_some());
        assert_eq!(back.at(&["exec_backend"]).as_str(), Some("resident"));
        assert_eq!(back.at(&["shards"]).as_f64(), Some(0.0));
        assert!(back.at(&["prefetch_stall_ms"]).as_f64().is_some());
        assert!(back.at(&["prefetch_occupancy"]).as_f64().is_some());
        assert!(back.at(&["ckpt_backpressure_wait_ms"]).as_f64().is_some());
        assert!(back.at(&["ckpt_submits"]).as_f64().unwrap() > 0.0);
        // Replication was off for the bench run: fields present, zero.
        assert_eq!(back.at(&["replica_lag_iters"]).as_f64(), Some(0.0));
        assert_eq!(back.at(&["replica_bytes"]).as_f64(), Some(0.0));
        assert_eq!(back.at(&["replica_retries"]).as_f64(), Some(0.0));
    }
}
