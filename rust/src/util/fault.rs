//! Deterministic fault injection (`cfg.faults`).
//!
//! A [`FaultPlan`] arms named **sites** across the stack — engine
//! `train_step` errors, prefetch worker panics, checkpoint sink I/O
//! errors after N bytes, torn `MANIFEST` reads, shard engine loss,
//! serve worker death, transient engine-fork failures — with a
//! schedule derived from the run RNG, so every injected failure is
//! bitwise reproducible.  Each site counts *hits* (times execution
//! passes through it) and fires at a configured or seeded-random hit,
//! for a configured number of consecutive hits.
//!
//! The plan is a plain `Arc` handle threaded explicitly through the
//! subsystems that honour it (trainer, backends, prefetcher, registry,
//! serve workers) — there is no process-global state, so parallel
//! tests with different plans never interfere.  Injected errors carry
//! a typed [`InjectedFault`] in their chain; the supervisor
//! (`coordinator::supervisor`) classifies those as transient by
//! construction.

use std::collections::BTreeMap;
use std::io::{self, Write};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// The trainer's per-iteration `train_step` call fails.
pub const SITE_TRAIN_STEP: &str = "engine.train_step";
/// The prefetch worker panics while assembling a batch.
pub const SITE_PREFETCH: &str = "data.prefetch";
/// The checkpoint sink returns an I/O error after `after_bytes` bytes.
pub const SITE_CKPT_SINK: &str = "checkpoint.sink";
/// A registry `MANIFEST.json` read comes back torn/corrupt.
pub const SITE_REGISTRY_READ: &str = "registry.read";
/// One shard's engine fails mid-step (recovered in place).
pub const SITE_SHARD_ENGINE: &str = "shard.engine";
/// A serve worker dies while holding a micro-batch.
pub const SITE_SERVE_WORKER: &str = "serve.worker";
/// An engine fork (shard recovery / worker respawn) fails transiently.
pub const SITE_POOL_FORK: &str = "pool.fork";
/// A replication upload truncates mid-transfer (after `after_bytes`
/// staged bytes when set) and errors.
pub const SITE_REPLICATE_UPLOAD: &str = "replicate.upload";
/// The remote manifest publish tears: partial bytes land at the final
/// path and the write errors.
pub const SITE_REPLICATE_MANIFEST: &str = "replicate.manifest";
/// A read from the remote store (manifest or checkpoint payload) fails
/// transiently.
pub const SITE_REMOTE_READ: &str = "remote.read";

/// Every site name the config parser and plan builder accept.
pub const KNOWN_SITES: &[&str] = &[
    SITE_TRAIN_STEP,
    SITE_PREFETCH,
    SITE_CKPT_SINK,
    SITE_REGISTRY_READ,
    SITE_SHARD_ENGINE,
    SITE_SERVE_WORKER,
    SITE_POOL_FORK,
    SITE_REPLICATE_UPLOAD,
    SITE_REPLICATE_MANIFEST,
    SITE_REMOTE_READ,
];

/// One armed site in `cfg.faults.sites`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSiteCfg {
    /// One of [`KNOWN_SITES`].
    pub site: String,
    /// 1-based hit index at which the site starts firing; `0` derives
    /// the index from the seeded schedule RNG (still deterministic).
    pub at: u64,
    /// Number of consecutive hits that fire (default 1).
    pub times: u64,
    /// `checkpoint.sink` / `replicate.upload` only: the sink accepts
    /// this many bytes before erroring (default: fail on the first
    /// write).
    pub after_bytes: Option<u64>,
}

/// The `faults` config section: injection sites plus the supervised
/// recovery policy (`coordinator::supervisor`).  Excluded from the
/// determinism fingerprint — a recovered run is bitwise identical to
/// the fault-free run, so it must also *fingerprint* identically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultsCfg {
    pub sites: Vec<FaultSiteCfg>,
    /// Supervisor retry budget: restore attempts after the first run.
    pub max_retries: u64,
    /// Base supervisor backoff in milliseconds; doubles per consecutive
    /// failure, plus deterministic jitter from the seeded RNG.
    pub backoff_ms: u64,
    /// XOR'd with the run seed to derive the injection schedule.
    pub seed: u64,
}

impl Default for FaultsCfg {
    fn default() -> Self {
        FaultsCfg { sites: Vec::new(), max_retries: 4, backoff_ms: 10, seed: 0 }
    }
}

impl FaultsCfg {
    /// True when at least one site is armed.
    pub fn enabled(&self) -> bool {
        !self.sites.is_empty()
    }
}

/// Typed marker carried in the chain of every injected error, so the
/// supervisor can classify injections as transient without string
/// matching.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub site: String,
}

impl InjectedFault {
    pub fn new(site: &str) -> Self {
        InjectedFault { site: site.to_string() }
    }
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected fault at {}", self.site)
    }
}

impl std::error::Error for InjectedFault {}

/// Returned by [`FaultPlan::hit`] when the site fires: `seq` is the
/// 0-based firing ordinal at that site (lets callers vary the victim
/// deterministically, e.g. which shard dies).
#[derive(Debug, Clone, Copy)]
pub struct FaultShot {
    pub seq: u64,
    pub after_bytes: Option<u64>,
}

#[derive(Debug)]
struct SiteState {
    fire_at: u64,
    times: u64,
    hits: u64,
    fired: u64,
    after_bytes: Option<u64>,
}

/// A compiled, shareable injection schedule.  All methods take `&self`;
/// per-site counters live behind one mutex, so the same plan can be
/// hit from the trainer thread, prefetch worker, checkpoint writer and
/// serve workers concurrently.
#[derive(Debug, Default)]
pub struct FaultPlan {
    sites: Mutex<BTreeMap<String, SiteState>>,
}

impl FaultPlan {
    /// Compile a plan.  Sites with `at == 0` draw their firing hit from
    /// `run_seed ^ cfg.seed` (window 1..=8), so "fail somewhere early"
    /// schedules are still reproducible.
    pub fn from_cfg(cfg: &FaultsCfg, run_seed: u64) -> Result<Arc<Self>> {
        let mut rng = Rng::seed_from_u64(run_seed ^ cfg.seed ^ 0xfa17_5eed);
        let mut sites = BTreeMap::new();
        for s in &cfg.sites {
            if !KNOWN_SITES.contains(&s.site.as_str()) {
                bail!(
                    "unknown fault site '{}' (known sites: {})",
                    s.site,
                    KNOWN_SITES.join(", ")
                );
            }
            if s.times == 0 {
                bail!("fault site '{}' arms zero firings (times = 0)", s.site);
            }
            let fire_at = if s.at == 0 { 1 + rng.below(8) as u64 } else { s.at };
            let state = SiteState {
                fire_at,
                times: s.times,
                hits: 0,
                fired: 0,
                after_bytes: s.after_bytes,
            };
            if sites.insert(s.site.clone(), state).is_some() {
                bail!("fault site '{}' is armed twice", s.site);
            }
        }
        Ok(Arc::new(FaultPlan { sites: Mutex::new(sites) }))
    }

    /// True when any site is armed (unarmed plans make every check a
    /// cheap no-op).
    pub fn armed(&self) -> bool {
        !self.lock().is_empty()
    }

    /// Count one pass through `site`; `Some(shot)` when this hit fires.
    pub fn hit(&self, site: &str) -> Option<FaultShot> {
        let mut g = self.lock();
        let st = g.get_mut(site)?;
        st.hits += 1;
        if st.hits >= st.fire_at && st.hits < st.fire_at + st.times {
            let seq = st.fired;
            st.fired += 1;
            Some(FaultShot { seq, after_bytes: st.after_bytes })
        } else {
            None
        }
    }

    /// [`hit`](Self::hit) as a `Result`: `Err(InjectedFault)` when the
    /// site fires (auto-converts into `anyhow::Error` via `?`).
    pub fn check(&self, site: &str) -> std::result::Result<(), InjectedFault> {
        match self.hit(site) {
            Some(_) => Err(InjectedFault::new(site)),
            None => Ok(()),
        }
    }

    /// How many times `site` has fired so far.
    pub fn fired(&self, site: &str) -> u64 {
        self.lock().get(site).map(|s| s.fired).unwrap_or(0)
    }

    /// Total firings across all sites.
    pub fn fired_total(&self) -> u64 {
        self.lock().values().map(|s| s.fired).sum()
    }

    /// A counter check must never be lost to a poisoned mutex (a panic
    /// between `lock()` and drop can only leave fully-written counter
    /// state behind).
    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, SiteState>> {
        self.sites.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// True when `err`'s chain carries an [`InjectedFault`] (works through
/// `anyhow` contexts and custom `io::Error` payloads).
pub fn is_injected(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<InjectedFault>().is_some())
}

/// The fault site carried in `err`'s chain, when the error is an
/// injection — lets the supervisor label recovery events in the run
/// trace without string matching.
pub fn injected_site(err: &anyhow::Error) -> Option<&str> {
    err.chain()
        .find_map(|c| c.downcast_ref::<InjectedFault>().map(|f| f.site.as_str()))
}

/// An `io::Write` adapter that accepts `budget` bytes and then fails
/// every write with an [`InjectedFault`]-carrying error — the
/// `checkpoint.sink` site ("disk full after N bytes").
pub struct FailingWriter<W> {
    inner: W,
    left: u64,
    tripped: bool,
}

impl<W: Write> FailingWriter<W> {
    pub fn new(inner: W, budget: Option<u64>) -> Self {
        FailingWriter { inner, left: budget.unwrap_or(0), tripped: false }
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for FailingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.tripped || buf.len() as u64 > self.left {
            self.tripped = true;
            return Err(io::Error::other(InjectedFault::new(SITE_CKPT_SINK)));
        }
        self.left -= buf.len() as u64;
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site(name: &str, at: u64, times: u64) -> FaultSiteCfg {
        FaultSiteCfg { site: name.into(), at, times, after_bytes: None }
    }

    #[test]
    fn explicit_schedule_fires_at_the_configured_hits() {
        let cfg = FaultsCfg {
            sites: vec![site(SITE_TRAIN_STEP, 3, 2)],
            ..Default::default()
        };
        let plan = FaultPlan::from_cfg(&cfg, 0).unwrap();
        assert!(plan.armed());
        let fired: Vec<bool> =
            (0..6).map(|_| plan.hit(SITE_TRAIN_STEP).is_some()).collect();
        assert_eq!(fired, [false, false, true, true, false, false]);
        assert_eq!(plan.fired(SITE_TRAIN_STEP), 2);
        assert_eq!(plan.fired_total(), 2);
        // shots number their firings
        let cfg = FaultsCfg {
            sites: vec![site(SITE_SHARD_ENGINE, 1, 3)],
            ..Default::default()
        };
        let plan = FaultPlan::from_cfg(&cfg, 0).unwrap();
        let seqs: Vec<u64> =
            (0..3).map(|_| plan.hit(SITE_SHARD_ENGINE).unwrap().seq).collect();
        assert_eq!(seqs, [0, 1, 2]);
    }

    #[test]
    fn derived_schedule_is_seed_deterministic() {
        let cfg = FaultsCfg {
            sites: vec![site(SITE_PREFETCH, 0, 1), site(SITE_TRAIN_STEP, 0, 1)],
            ..Default::default()
        };
        let fire_pattern = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::from_cfg(&cfg, seed).unwrap();
            (0..10).map(|_| plan.hit(SITE_PREFETCH).is_some()).collect()
        };
        assert_eq!(fire_pattern(7), fire_pattern(7), "same seed, same schedule");
        assert_eq!(fire_pattern(7).iter().filter(|f| **f).count(), 1);
        assert!(fire_pattern(7)[..8].contains(&true), "derived hit is in 1..=8");
    }

    #[test]
    fn unarmed_sites_never_fire_and_unknown_sites_are_rejected() {
        let plan = FaultPlan::from_cfg(&FaultsCfg::default(), 0).unwrap();
        assert!(!plan.armed());
        assert!(plan.hit(SITE_TRAIN_STEP).is_none());
        assert!(plan.check(SITE_REGISTRY_READ).is_ok());

        let bad = FaultsCfg { sites: vec![site("disk.melt", 1, 1)], ..Default::default() };
        let err = FaultPlan::from_cfg(&bad, 0).unwrap_err();
        assert!(format!("{err:#}").contains("disk.melt"));
        let dup = FaultsCfg {
            sites: vec![site(SITE_PREFETCH, 1, 1), site(SITE_PREFETCH, 2, 1)],
            ..Default::default()
        };
        assert!(FaultPlan::from_cfg(&dup, 0).is_err());
        let zero = FaultsCfg { sites: vec![site(SITE_PREFETCH, 1, 0)], ..Default::default() };
        assert!(FaultPlan::from_cfg(&zero, 0).is_err());
    }

    #[test]
    fn injected_errors_are_typed_through_anyhow_chains() {
        let cfg = FaultsCfg {
            sites: vec![site(SITE_REGISTRY_READ, 1, 1)],
            ..Default::default()
        };
        let plan = FaultPlan::from_cfg(&cfg, 0).unwrap();
        let err: anyhow::Error = plan
            .check(SITE_REGISTRY_READ)
            .map_err(anyhow::Error::new)
            .unwrap_err()
            .context("reading MANIFEST.json");
        assert!(is_injected(&err), "marker lost through context: {err:#}");
        assert!(format!("{err:#}").contains(SITE_REGISTRY_READ));
        let real = anyhow::anyhow!("disk actually full");
        assert!(!is_injected(&real));
    }

    #[test]
    fn failing_writer_trips_after_its_byte_budget() {
        let mut w = FailingWriter::new(Vec::new(), Some(8));
        assert_eq!(w.write(b"1234").unwrap(), 4);
        assert_eq!(w.write(b"5678").unwrap(), 4);
        let err = w.write(b"9").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Other);
        // the typed marker survives io::Error -> anyhow conversion
        let any = anyhow::Error::new(err).context("writing checkpoint");
        assert!(is_injected(&any), "marker lost: {any:#}");
        assert_eq!(w.into_inner(), b"12345678");

        // no budget: the very first write fails
        let mut w = FailingWriter::new(Vec::new(), None);
        assert!(w.write(b"x").is_err());
    }
}
