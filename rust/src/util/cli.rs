//! Tiny argv parser (no `clap` on the offline testbed): positional
//! subcommand + `--flag value` / `--flag` options.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an argv tail (without the program name).  A `--name` token
    /// followed by a non-flag token is a valued option; otherwise it's a
    /// boolean switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    /// Comma-separated integer list, e.g. `--clients 2,8,32`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => {
                let parsed: Vec<usize> = v
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        s.parse::<usize>().map_err(|_| {
                            anyhow!("--{name} expects a comma-separated integer list")
                        })
                    })
                    .collect::<Result<_>>()?;
                if parsed.is_empty() {
                    // `--clients ,` must not silently mean "no levels".
                    return Err(anyhow!("--{name} got an empty list"));
                }
                Ok(parsed)
            }
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number")),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --family resnet8 --iters 300 --smd");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("family"), Some("resnet8"));
        assert_eq!(a.u64_or("iters", 0).unwrap(), 300);
        assert!(a.bool("smd"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn equals_form() {
        let a = parse("exp --iters=42 --out=results");
        assert_eq!(a.u64_or("iters", 0).unwrap(), 42);
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.f64_or("missing", 1.5).unwrap(), 1.5);
        assert!(a.u64_or("n", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse("serve --clients 2,8,32 --bad 1,x");
        assert_eq!(a.usize_list_or("clients", &[1]).unwrap(), vec![2, 8, 32]);
        assert_eq!(a.usize_list_or("missing", &[4, 16]).unwrap(), vec![4, 16]);
        assert!(a.usize_list_or("bad", &[1]).is_err());
        // trailing commas / spaces are tolerated
        let b = parse("serve --clients=2,");
        assert_eq!(b.usize_list_or("clients", &[1]).unwrap(), vec![2]);
        // an all-empty list is an error, not a silent no-op
        let c = parse("serve --clients=,");
        assert!(c.usize_list_or("clients", &[1]).is_err());
    }
}
