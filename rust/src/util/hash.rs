//! FNV-1a 64-bit hashing — the content-hash substrate for the
//! checkpoint subsystem (no sha2/xxhash crates on the offline testbed).
//!
//! FNV-1a is not cryptographic; it guards against *corruption*
//! (truncated writes, bit rot, torn reads), which is exactly the threat
//! model for `ckpt/v1` files and the run-config fingerprint.  The
//! streaming form lets large tensor payloads hash without an extra
//! concatenation pass.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// `fnv1a64` rendered the way registries and fingerprints store it.
pub fn fnv1a64_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

/// Streaming FNV-1a 64 hasher.
#[derive(Debug, Clone)]
pub struct Fnv64 {
    h: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Self { h: FNV_OFFSET }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut h = Fnv64::new();
        h.update(&data[..10]);
        h.update(&data[10..]);
        assert_eq!(h.finish(), fnv1a64(data));
    }

    #[test]
    fn hex_is_sixteen_chars() {
        assert_eq!(fnv1a64_hex(b"x").len(), 16);
    }
}
