//! Deterministic PRNG substrate (no `rand` on the offline testbed).
//!
//! xoshiro256** seeded via SplitMix64 — the standard pairing recommended
//! by the xoshiro authors.  Every stochastic component of the coordinator
//! (init, sampler, SMD, SD, synthetic data) draws from its own seeded
//! instance so runs are exactly reproducible and streams are independent.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// The raw xoshiro256** state, exported for checkpoints: a
    /// generator rebuilt via [`Rng::from_state`] continues the exact
    /// output stream from this point.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from an exported state.  The all-zero state
    /// is xoshiro's absorbing fixed point (every output would be 0) and
    /// can never be reached from a seeded generator, so it only arises
    /// from corruption — rejected with `None`.
    pub fn from_state(s: [u64; 4]) -> Option<Self> {
        if s == [0u64; 4] {
            None
        } else {
            Some(Self { s })
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Signed offset in [-k, k] inclusive.
    pub fn offset(&mut self, k: isize) -> isize {
        self.range_usize(0, (2 * k) as usize) as isize - k
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(f64::EPSILON);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::seed_from_u64(9);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state()).unwrap();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the corrupt all-zero state is rejected, never constructed
        assert!(Rng::from_state([0; 4]).is_none());
    }

    #[test]
    fn uniform_statistics() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 / 10_000.0 - 1.0).abs() < 0.08, "count {c}");
        }
    }

    #[test]
    fn normal_statistics() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let vals: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f64 = vals.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let var: f64 =
            vals.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn bool_respects_p() {
        let mut r = Rng::seed_from_u64(4);
        let hits = (0..50_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 50_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn offset_bounds() {
        let mut r = Rng::seed_from_u64(6);
        for _ in 0..1000 {
            let o = r.offset(4);
            assert!((-4..=4).contains(&o));
        }
    }
}
