//! In-repo substrates for the offline testbed (no crates.io access
//! beyond `xla`/`anyhow`):
//!
//! * [`json`] — JSON parser/writer (replaces serde_json)
//! * [`rng`] — xoshiro256** PRNG (replaces rand)
//! * [`cli`] — argv parsing (replaces clap)
//! * [`bench`] — micro-bench harness (replaces criterion)
//! * [`perf`] — host-vs-resident step-path comparisons (BENCH_runtime.json)
//! * [`prop`] — seeded property testing (replaces proptest)
//! * [`tmp`] — scratch dirs for tests (replaces tempfile)
//! * [`hash`] — FNV-1a 64 content hashing (checkpoint files/fingerprints)
//! * [`fault`] — deterministic fault injection (seeded, named sites)

pub mod bench;
pub mod cli;
pub mod fault;
pub mod hash;
pub mod json;
pub mod perf;
pub mod prop;
pub mod rng;
pub mod tmp;

pub use json::Json;
pub use rng::Rng;
