//! Minimal JSON: a recursive-descent parser + writer.
//!
//! The offline testbed ships no serde_json, so this module is the
//! in-repo substrate for the two JSON surfaces the system needs: the
//! machine-generated artifact manifests from `aot.py` (parse) and the
//! experiment/metric records (write).  It supports the full JSON value
//! grammar (objects, arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for misses.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&Json::Null);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|v| v as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // Checked variants with contextful errors (manifest parsing).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing string field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing number field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing array field '{key}'"))
    }

    // ---------------- writer ----------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------------- builders ----------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ==========================================================================
// Parser
// ==========================================================================

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        self.pos += 1;
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => bail!(
                "expected '{}' at offset {}, found {:?}",
                b as char,
                self.pos - 1,
                other.map(|c| c as char)
            ),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| anyhow!("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => bail!("bad escape {:?}", other.map(|c| c as char)),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| anyhow!("invalid utf8 in string"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": "x\ny"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.at(&["a"]).as_f64(), Some(1.0));
        assert_eq!(v.at(&["b"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["b"]).as_arr().unwrap()[2].as_f64(), Some(-2500.0));
        assert_eq!(v.at(&["c"]).as_str(), Some("x\ny"));
        // reparse what we print
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn nested_objects() {
        let v = parse(r#"{"a":{"b":{"c":[{"d":7}]}}}"#).unwrap();
        assert_eq!(
            v.at(&["a", "b", "c"]).as_arr().unwrap()[0]
                .get("d")
                .unwrap()
                .as_f64(),
            Some(7.0)
        );
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#"{"s": "Aµλ😀"}"#).unwrap();
        assert_eq!(v.at(&["s"]).as_str(), Some("Aµλ😀"));
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/resnet8-c10-tiny/sgd32.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let v = parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(v.at(&["family"]).as_str(), Some("resnet8-c10-tiny"));
        assert!(v.at(&["total_flops"]).as_f64().unwrap() > 0.0);
    }
}
