//! Property-testing substrate (no `proptest` on the offline testbed):
//! run a property over many seeded random cases; on failure report the
//! seed so the case replays exactly.
//!
//! Usage:
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.range_usize(1, 64);
//!     ...
//!     assert!(invariant);
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random trials of `property`, panicking with the failing
/// seed on the first violation (assert inside the closure).
pub fn check<F: FnMut(&mut Rng)>(cases: u64, mut property: F) {
    for case in 0..cases {
        let seed = 0xE27A_1000 + case;
        let mut rng = Rng::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single seed (debugging helper).
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut property: F) {
    let mut rng = Rng::seed_from_u64(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |rng| {
            let a = rng.range_usize(0, 100);
            let b = rng.range_usize(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn reports_failures() {
        check(50, |rng| {
            assert!(rng.f64() < 0.9, "intentional failure");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut v1 = 0.0;
        replay(7, |rng| v1 = rng.f64());
        let mut v2 = 0.0;
        replay(7, |rng| v2 = rng.f64());
        assert_eq!(v1, v2);
    }
}
