//! Procedural CIFAR-like dataset (DESIGN.md §Substitutions).
//!
//! The testbed has no network access, so CIFAR-10/100 is replaced by a
//! class-conditional texture generator: every class owns a small set of
//! oriented sinusoidal gratings (frequency, orientation, phase), a color
//! tint, and a blob layout; samples draw per-instance jitter + pixel
//! noise.  The task is learnable but non-trivial (a linear probe gets it
//! badly wrong; a small CNN separates classes well) — exactly what's
//! needed to preserve the *ordering* between training methods that the
//! paper's tables report.

use crate::util::Rng;

use super::Dataset;

/// Per-class texture recipe, derived deterministically from (seed, class).
struct ClassProto {
    freqs: [f32; 2],
    thetas: [f32; 2],
    tint: [f32; 3],
    blob_xy: (f32, f32),
    blob_sigma: f32,
}

impl ClassProto {
    fn new(seed: u64, class: usize) -> Self {
        let mut rng = Rng::seed_from_u64(
            seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(class as u64 + 1),
        );
        Self {
            freqs: [rng.range_f32(1.5, 5.0), rng.range_f32(2.0, 7.0)],
            thetas: [
                rng.range_f32(0.0, std::f32::consts::PI),
                rng.range_f32(0.0, std::f32::consts::PI),
            ],
            tint: [
                rng.range_f32(-0.6, 0.6),
                rng.range_f32(-0.6, 0.6),
                rng.range_f32(-0.6, 0.6),
            ],
            blob_xy: (rng.range_f32(0.2, 0.8), rng.range_f32(0.2, 0.8)),
            blob_sigma: rng.range_f32(0.12, 0.3),
        }
    }
}

/// Generate `n` samples of `classes` classes at `hw` x `hw` x 3, balanced
/// across classes, shuffled, values roughly zero-mean unit-ish variance
/// (the normalization the paper applies to CIFAR [60] is baked in).
///
/// `seed` fixes the *class prototypes* (the task); use [`generate_split`]
/// to draw disjoint train/test sample streams from the same task.
pub fn generate(classes: usize, n: usize, hw: usize, seed: u64) -> Dataset {
    generate_stream(classes, n, hw, seed, 0)
}

/// Same task (prototypes from `seed`), different per-sample noise stream.
/// Train and test sets MUST share `seed` and differ in `stream` — the
/// class definitions live in the prototypes.
pub fn generate_stream(
    classes: usize,
    n: usize,
    hw: usize,
    seed: u64,
    stream: u64,
) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
    let protos: Vec<ClassProto> =
        (0..classes).map(|c| ClassProto::new(seed, c)).collect();

    let mut images = vec![0f32; n * hw * hw * 3];
    let mut labels = vec![0i32; n];
    for i in 0..n {
        let c = i % classes;
        labels[i] = c as i32;
        let p = &protos[c];
        // per-sample jitter
        let phase: [f32; 2] = [rng.range_f32(0.0, 6.283), rng.range_f32(0.0, 6.283)];
        let freq_j: f32 = rng.range_f32(0.9, 1.1);
        let theta_j: f32 = rng.range_f32(-0.12, 0.12);
        let bx = p.blob_xy.0 + rng.range_f32(-0.08, 0.08);
        let by = p.blob_xy.1 + rng.range_f32(-0.08, 0.08);
        let amp: f32 = rng.range_f32(0.7, 1.3);

        let base = i * hw * hw * 3;
        for yy in 0..hw {
            for xx in 0..hw {
                let u = xx as f32 / hw as f32;
                let v = yy as f32 / hw as f32;
                let mut g = 0.0f32;
                for k in 0..2 {
                    let th = p.thetas[k] + theta_j;
                    let f = p.freqs[k] * freq_j;
                    let proj = u * th.cos() + v * th.sin();
                    g += (proj * f * std::f32::consts::TAU + phase[k]).sin();
                }
                let d2 = (u - bx).powi(2) + (v - by).powi(2);
                let blob = (-d2 / (2.0 * p.blob_sigma * p.blob_sigma)).exp();
                let tex = amp * (0.5 * g + blob);
                let px = base + (yy * hw + xx) * 3;
                for ch in 0..3 {
                    // Heavy pixel noise + weak class signal keep the task
                    // non-saturating at the testbed's training budgets, so
                    // method orderings (SMD vs SMB etc.) stay measurable.
                    let noise: f32 = rng.range_f32(-1.0, 1.0);
                    images[px + ch] =
                        0.28 * tex * (1.0 + p.tint[ch]) + p.tint[ch] * 0.12 + 0.75 * noise;
                }
            }
        }
    }

    // Shuffle (Fisher-Yates) so class order carries no information.
    let img_stride = hw * hw * 3;
    for i in (1..n).rev() {
        let j = rng.below(i + 1);
        labels.swap(i, j);
        if i != j {
            let (a, b) = (i * img_stride, j * img_stride);
            for k in 0..img_stride {
                images.swap(a + k, b + k);
            }
        }
    }

    Dataset { images, labels, n, hw, classes }
}

/// (train, test) drawn from the same class prototypes, disjoint noise.
pub fn generate_split(
    classes: usize,
    n_train: usize,
    n_test: usize,
    hw: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    (
        generate_stream(classes, n_train, hw, seed, 1),
        generate_stream(classes, n_test, hw, seed, 2),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_and_deterministic() {
        let d1 = generate(10, 200, 8, 42);
        let d2 = generate(10, 200, 8, 42);
        assert_eq!(d1.images, d2.images);
        assert_eq!(d1.labels, d2.labels);
        for c in 0..10 {
            assert_eq!(d1.labels.iter().filter(|&&l| l == c).count(), 20);
        }
    }

    #[test]
    fn seeds_differ() {
        let d1 = generate(10, 50, 8, 1);
        let d2 = generate(10, 50, 8, 2);
        assert_ne!(d1.images, d2.images);
    }

    #[test]
    fn split_shares_task_but_not_samples() {
        let (tr, te) = generate_split(4, 200, 100, 8, 9);
        assert_ne!(tr.images[..100], te.images[..100]);
        // cross-set nearest-class-mean works: train means classify test.
        let stride = 8 * 8 * 3;
        let mut means = vec![vec![0f32; stride]; 4];
        let mut counts = [0usize; 4];
        for i in 0..tr.n {
            let c = tr.labels[i] as usize;
            counts[c] += 1;
            for k in 0..stride {
                means[c][k] += tr.images[i * stride + k];
            }
        }
        for c in 0..4 {
            for k in 0..stride {
                means[c][k] /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..te.n {
            let mut best = (f32::MAX, 0usize);
            for c in 0..4 {
                let d: f32 = (0..stride)
                    .map(|k| (te.images[i * stride + k] - means[c][k]).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == te.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / te.n as f32;
        assert!(acc > 0.5, "cross-set acc {acc} (chance 0.25)");
    }

    #[test]
    fn pixel_statistics_reasonable() {
        let d = generate(10, 100, 16, 3);
        let mean: f32 = d.images.iter().sum::<f32>() / d.images.len() as f32;
        let var: f32 = d
            .images
            .iter()
            .map(|v| (v - mean).powi(2))
            .sum::<f32>()
            / d.images.len() as f32;
        assert!(mean.abs() < 0.5, "mean {mean}");
        assert!(var > 0.05 && var < 4.0, "var {var}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-class-mean in pixel space beats chance by a wide margin
        // on held-out samples — the generator carries class signal.
        let d = generate(4, 400, 8, 7);
        let stride = 8 * 8 * 3;
        let (train_n, test_n) = (300, 100);
        let mut means = vec![vec![0f32; stride]; 4];
        let mut counts = [0usize; 4];
        for i in 0..train_n {
            let c = d.labels[i] as usize;
            counts[c] += 1;
            for k in 0..stride {
                means[c][k] += d.images[i * stride + k];
            }
        }
        for c in 0..4 {
            for k in 0..stride {
                means[c][k] /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in train_n..train_n + test_n {
            let mut best = (f32::MAX, 0usize);
            for c in 0..4 {
                let dist: f32 = (0..stride)
                    .map(|k| (d.images[i * stride + k] - means[c][k]).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / test_n as f32;
        assert!(acc > 0.5, "nearest-mean acc {acc} (chance 0.25)");
    }
}
