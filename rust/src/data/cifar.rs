//! Loader for the real CIFAR-10 binary format (`data_batch_*.bin`).
//!
//! Used automatically when `data/cifar-10-batches-bin` exists next to the
//! workspace (the testbed is offline, so the synthetic generator is the
//! default); each record is 1 label byte + 3072 CHW bytes.  Pixels are
//! normalized with the CIFAR channel statistics as in [60].
//!
//! Ingestion is **streaming**: [`open`] validates the files and counts
//! records from metadata alone (cheap — no decode), and
//! [`CifarFiles::decode`] reads record-at-a-time through a `BufReader`,
//! so raw file bytes never sit fully in memory next to the decoded f32
//! dataset.  The trainer defers `decode` to the prefetch worker when
//! prefetching is on, so the main thread never materializes the training
//! set (`coordinator::trainer`); the decoded floats are byte-for-byte
//! what an eager whole-file load produced, keeping the batch stream
//! bitwise identical.

use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::Dataset;

const REC: usize = 1 + 3072;
const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

/// Validated handle to a set of CIFAR binaries: paths + total record
/// count, decode deferred.  Cloneable so a trainer can hand one to the
/// prefetch worker per run.
#[derive(Debug, Clone)]
pub struct CifarFiles {
    files: Vec<PathBuf>,
    /// Total records across all files (from file sizes).
    pub n: usize,
}

/// Open the `data_batch_*.bin` (train) or `test_batch.bin` (test) set:
/// existence + size validation and record counting only — no decode.
pub fn open(dir: &Path, train: bool) -> Result<CifarFiles> {
    let files: Vec<PathBuf> = if train {
        (1..=5).map(|i| dir.join(format!("data_batch_{i}.bin"))).collect()
    } else {
        vec![dir.join("test_batch.bin")]
    };
    let mut n = 0;
    for f in &files {
        let meta = std::fs::metadata(f)
            .with_context(|| format!("missing CIFAR file {}", f.display()))?;
        let len = meta.len() as usize;
        if len % REC != 0 {
            bail!("{}: size {} not a multiple of {}", f.display(), len, REC);
        }
        n += len / REC;
    }
    Ok(CifarFiles { files, n })
}

impl CifarFiles {
    /// Stream-decode every record into a [`Dataset`].  Reads through a
    /// bounded `BufReader` one record at a time (the old loader slurped
    /// each whole file first), producing bit-identical floats in the
    /// identical order.
    pub fn decode(&self) -> Result<Dataset> {
        let mut images = Vec::with_capacity(self.n * 3072);
        let mut labels = Vec::with_capacity(self.n);
        let mut rec = [0u8; REC];
        for f in &self.files {
            let file = std::fs::File::open(f)
                .with_context(|| format!("opening CIFAR file {}", f.display()))?;
            // Re-check the size at decode time: open() may have run on a
            // different thread (or much earlier) than this worker-side
            // decode, and a short final read should name the file.
            let len = file.metadata()?.len() as usize;
            if len % REC != 0 {
                bail!("{}: size {} not a multiple of {}", f.display(), len, REC);
            }
            let mut reader = std::io::BufReader::with_capacity(64 * REC, file);
            for _ in 0..len / REC {
                reader
                    .read_exact(&mut rec)
                    .with_context(|| format!("reading {}", f.display()))?;
                labels.push(rec[0] as i32);
                decode_record(&rec, &mut images);
            }
        }
        let n = labels.len();
        Ok(Dataset { images, labels, n, hw: 32, classes: 10 })
    }
}

/// CHW bytes -> normalized HWC f32 (the per-record decode both the old
/// eager loader and the streaming path share).
fn decode_record(rec: &[u8; REC], images: &mut Vec<f32>) {
    for y in 0..32 {
        for x in 0..32 {
            for c in 0..3 {
                let v = rec[1 + c * 1024 + y * 32 + x] as f32 / 255.0;
                images.push((v - MEAN[c]) / STD[c]);
            }
        }
    }
}

/// Load all `data_batch_*.bin` (train) or `test_batch.bin` (test)
/// records eagerly — `open(..)?.decode()`.
pub fn load(dir: &Path, train: bool) -> Result<Dataset> {
    open(dir, train)?.decode()
}

/// True when a usable CIFAR-10 binary directory is present.
pub fn available(dir: &Path) -> bool {
    dir.join("data_batch_1.bin").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;
    use std::io::Write;

    #[test]
    fn parses_synthetic_records() {
        let dir = TempDir::new().unwrap();
        let mut bytes = Vec::new();
        for i in 0..4u8 {
            bytes.push(i % 10);
            bytes.extend(std::iter::repeat(128u8).take(3072));
        }
        let mut f =
            std::fs::File::create(dir.path().join("test_batch.bin")).unwrap();
        f.write_all(&bytes).unwrap();
        let d = load(dir.path(), false).unwrap();
        assert_eq!(d.n, 4);
        assert_eq!(d.hw, 32);
        assert_eq!(d.labels, vec![0, 1, 2, 3]);
        // 128/255 normalized with channel-0 stats
        let expect = (128.0 / 255.0 - MEAN[0]) / STD[0];
        assert!((d.images[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn open_counts_without_decoding() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("test_batch.bin"), vec![0u8; 3 * REC])
            .unwrap();
        let files = open(dir.path(), false).unwrap();
        assert_eq!(files.n, 3);
        let d = files.decode().unwrap();
        assert_eq!(d.n, 3);
    }

    #[test]
    fn rejects_bad_size() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("test_batch.bin"), [0u8; 100]).unwrap();
        assert!(load(dir.path(), false).is_err());
        assert!(open(dir.path(), false).is_err());
    }

    #[test]
    fn missing_train_files_error() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("data_batch_1.bin"), vec![0u8; REC]).unwrap();
        // data_batch_2..5 missing
        assert!(open(dir.path(), true).is_err());
    }

    #[test]
    fn availability_probe() {
        let dir = TempDir::new().unwrap();
        assert!(!available(dir.path()));
        std::fs::write(dir.path().join("data_batch_1.bin"), [0u8; REC]).unwrap();
        assert!(available(dir.path()));
    }
}
