//! Loader for the real CIFAR-10 binary format (`data_batch_*.bin`).
//!
//! Used automatically when `data/cifar-10-batches-bin` exists next to the
//! workspace (the testbed is offline, so the synthetic generator is the
//! default); each record is 1 label byte + 3072 CHW bytes.  Pixels are
//! normalized with the CIFAR channel statistics as in [60].

use std::path::Path;

use anyhow::{bail, Result};

use super::Dataset;

const REC: usize = 1 + 3072;
const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

/// Load all `data_batch_*.bin` (train) or `test_batch.bin` (test) records.
pub fn load(dir: &Path, train: bool) -> Result<Dataset> {
    let files: Vec<std::path::PathBuf> = if train {
        (1..=5).map(|i| dir.join(format!("data_batch_{i}.bin"))).collect()
    } else {
        vec![dir.join("test_batch.bin")]
    };
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for f in files {
        if !f.exists() {
            bail!("missing CIFAR file {}", f.display());
        }
        let bytes = std::fs::read(&f)?;
        if bytes.len() % REC != 0 {
            bail!("{}: size {} not a multiple of {}", f.display(), bytes.len(), REC);
        }
        for rec in bytes.chunks_exact(REC) {
            labels.push(rec[0] as i32);
            // CHW bytes -> normalized HWC f32
            for y in 0..32 {
                for x in 0..32 {
                    for c in 0..3 {
                        let v = rec[1 + c * 1024 + y * 32 + x] as f32 / 255.0;
                        images.push((v - MEAN[c]) / STD[c]);
                    }
                }
            }
        }
    }
    let n = labels.len();
    Ok(Dataset { images, labels, n, hw: 32, classes: 10 })
}

/// True when a usable CIFAR-10 binary directory is present.
pub fn available(dir: &Path) -> bool {
    dir.join("data_batch_1.bin").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;
    use std::io::Write;

    #[test]
    fn parses_synthetic_records() {
        let dir = TempDir::new().unwrap();
        let mut bytes = Vec::new();
        for i in 0..4u8 {
            bytes.push(i % 10);
            bytes.extend(std::iter::repeat(128u8).take(3072));
        }
        let mut f =
            std::fs::File::create(dir.path().join("test_batch.bin")).unwrap();
        f.write_all(&bytes).unwrap();
        let d = load(dir.path(), false).unwrap();
        assert_eq!(d.n, 4);
        assert_eq!(d.hw, 32);
        assert_eq!(d.labels, vec![0, 1, 2, 3]);
        // 128/255 normalized with channel-0 stats
        let expect = (128.0 / 255.0 - MEAN[0]) / STD[0];
        assert!((d.images[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_size() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("test_batch.bin"), [0u8; 100]).unwrap();
        assert!(load(dir.path(), false).is_err());
    }

    #[test]
    fn availability_probe() {
        let dir = TempDir::new().unwrap();
        assert!(!available(dir.path()));
        std::fs::write(dir.path().join("data_batch_1.bin"), [0u8; REC]).unwrap();
        assert!(available(dir.path()));
    }
}
