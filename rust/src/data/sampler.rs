//! Mini-batch sampling: per-epoch permutations (the standard
//! without-replacement protocol the paper's SMD analysis contrasts with)
//! plus standard CIFAR augmentation (4-px pad + random crop, horizontal
//! flip) applied on the fly in rust — never in the HLO.

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, TensorData};
use crate::util::Rng;

use super::Dataset;

#[derive(Debug, Clone, Copy)]
pub struct AugmentCfg {
    pub pad: usize,
    pub flip: bool,
    pub enabled: bool,
}

impl Default for AugmentCfg {
    fn default() -> Self {
        Self { pad: 4, flip: true, enabled: true }
    }
}

/// Deterministic batch sampler over a dataset.
pub struct Sampler {
    rng: Rng,
    perm: Vec<usize>,
    cursor: usize,
    pub epoch: u64,
    batch: usize,
    augment: AugmentCfg,
}

impl Sampler {
    pub fn new(dataset_len: usize, batch: usize, augment: AugmentCfg, seed: u64) -> Self {
        let mut s = Self {
            rng: Rng::seed_from_u64(seed),
            perm: (0..dataset_len).collect(),
            cursor: 0,
            epoch: 0,
            batch,
            augment,
        };
        s.shuffle();
        s
    }

    fn shuffle(&mut self) {
        let mut rng = self.rng.clone();
        rng.shuffle(&mut self.perm);
        self.rng = rng;
        self.cursor = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.perm.len() / self.batch
    }

    /// Next batch of (x, y) host tensors; reshuffles between epochs.
    pub fn next_batch(&mut self, data: &Dataset) -> (HostTensor, HostTensor) {
        if self.cursor + self.batch > self.perm.len() {
            self.epoch += 1;
            self.shuffle();
        }
        let hw = data.hw;
        let stride = hw * hw * 3;
        let mut x = vec![0f32; self.batch * stride];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            let idx = self.perm[self.cursor + b];
            y[b] = data.labels[idx];
            let src = &data.images[idx * stride..(idx + 1) * stride];
            let dst = &mut x[b * stride..(b + 1) * stride];
            if self.augment.enabled {
                let pad = self.augment.pad as isize;
                let dy = self.rng.offset(pad);
                let dx = self.rng.offset(pad);
                let flip = self.augment.flip && self.rng.bool(0.5);
                crop_flip(src, dst, hw, dy, dx, flip);
            } else {
                dst.copy_from_slice(src);
            }
        }
        self.cursor += self.batch;
        (
            HostTensor::f32(vec![self.batch, hw, hw, 3], x),
            HostTensor::i32(vec![self.batch], y),
        )
    }
}

/// Contiguous per-shard row ranges covering `0..n`: up to `shards`
/// non-empty ranges whose sizes differ by at most one (the leading
/// ranges absorb the remainder of a non-divisible split).  Concatenated
/// in order they reproduce the original batch exactly, which is what
/// keeps the sharded reduction's sample order — and therefore its
/// floats — identical to the single-device pass (`runtime::shard`).
pub fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let s = shards.max(1).min(n);
    let base = n / s;
    let rem = n % s;
    let mut out = Vec::with_capacity(s);
    let mut lo = 0;
    for i in 0..s {
        let len = base + usize::from(i < rem);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

/// Slice rows `range` out of an assembled `(x, y)` batch — the
/// per-shard view of one training batch.  Row payloads are copied
/// verbatim (augmentation already happened upstream in the sampler /
/// prefetch worker), so shard slicing never perturbs the batch stream.
pub fn slice_batch(
    x: &HostTensor,
    y: &HostTensor,
    range: std::ops::Range<usize>,
) -> Result<(HostTensor, HostTensor)> {
    let b = x.shape.first().copied().unwrap_or(0);
    if range.start >= range.end || range.end > b {
        bail!("shard slice {range:?} out of range for batch of {b}");
    }
    let stride: usize = x.shape[1..].iter().product();
    let xs = x.as_f32()?;
    let ys = match &y.data {
        TensorData::I32(v) => v,
        _ => bail!("labels must be i32"),
    };
    if ys.len() != b {
        bail!("labels hold {} rows, batch has {b}", ys.len());
    }
    let mut shape = x.shape.clone();
    shape[0] = range.len();
    Ok((
        HostTensor::f32(
            shape,
            xs[range.start * stride..range.end * stride].to_vec(),
        ),
        HostTensor::i32(vec![range.len()], ys[range].to_vec()),
    ))
}

/// Shift-crop with zero padding + optional horizontal flip (HWC layout).
fn crop_flip(src: &[f32], dst: &mut [f32], hw: usize, dy: isize, dx: isize, flip: bool) {
    for yy in 0..hw {
        for xx in 0..hw {
            let sy = yy as isize + dy;
            let sx_raw = xx as isize + dx;
            let sx = if flip { hw as isize - 1 - sx_raw } else { sx_raw };
            let d = (yy * hw + xx) * 3;
            if sy >= 0 && sy < hw as isize && sx >= 0 && sx < hw as isize {
                let s = (sy as usize * hw + sx as usize) * 3;
                dst[d..d + 3].copy_from_slice(&src[s..s + 3]);
            } else {
                dst[d..d + 3].fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn batches_cover_epoch_without_replacement() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut s = Sampler::new(
            d.n,
            16,
            AugmentCfg { enabled: false, ..Default::default() },
            1,
        );
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let (_, y) = s.next_batch(&d);
            for v in y_as_vec(&y) {
                seen.insert(v);
            }
        }
        // 64 samples / 10 classes: all classes seen in one epoch.
        assert_eq!(seen.len(), 10);
        assert_eq!(s.epoch, 0);
        let _ = s.next_batch(&d);
        assert_eq!(s.epoch, 1);
    }

    fn y_as_vec(t: &HostTensor) -> Vec<i32> {
        match &t.data {
            crate::runtime::TensorData::I32(v) => v.clone(),
            _ => panic!(),
        }
    }

    #[test]
    fn augmentation_changes_pixels_not_labels() {
        let d = synthetic::generate(10, 32, 8, 0);
        let mut s1 = Sampler::new(d.n, 32, AugmentCfg::default(), 3);
        let mut s2 = Sampler::new(
            d.n,
            32,
            AugmentCfg { enabled: false, ..Default::default() },
            3,
        );
        let (x1, y1) = s1.next_batch(&d);
        let (x2, y2) = s2.next_batch(&d);
        assert_eq!(y_as_vec(&y1), y_as_vec(&y2));
        assert_ne!(x1.as_f32().unwrap(), x2.as_f32().unwrap());
    }

    #[test]
    fn crop_zero_shift_is_identity() {
        let src: Vec<f32> = (0..4 * 4 * 3).map(|i| i as f32).collect();
        let mut dst = vec![0f32; src.len()];
        crop_flip(&src, &mut dst, 4, 0, 0, false);
        assert_eq!(src, dst);
    }

    #[test]
    fn flip_reverses_rows() {
        let src: Vec<f32> = (0..2 * 2 * 3).map(|i| i as f32).collect();
        let mut dst = vec![0f32; src.len()];
        crop_flip(&src, &mut dst, 2, 0, 0, true);
        // pixel (0,0) <- (0,1)
        assert_eq!(dst[0..3], src[3..6]);
        assert_eq!(dst[3..6], src[0..3]);
    }

    #[test]
    fn shard_ranges_cover_and_balance() {
        assert_eq!(shard_ranges(8, 1), vec![0..8]);
        assert_eq!(shard_ranges(8, 2), vec![0..4, 4..8]);
        // non-divisible: leading shards take the remainder
        assert_eq!(shard_ranges(8, 3), vec![0..3, 3..6, 6..8]);
        // more shards than rows: only non-empty ranges come back
        assert_eq!(shard_ranges(2, 5), vec![0..1, 1..2]);
        assert!(shard_ranges(0, 4).is_empty());
        // concatenation always reproduces 0..n
        for (n, s) in [(7, 3), (16, 5), (9, 9), (10, 1)] {
            let rs = shard_ranges(n, s);
            let mut lo = 0;
            for r in &rs {
                assert_eq!(r.start, lo);
                lo = r.end;
            }
            assert_eq!(lo, n);
        }
    }

    #[test]
    fn slice_batch_preserves_rows() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut s = Sampler::new(d.n, 8, AugmentCfg::default(), 2);
        let (x, y) = s.next_batch(&d);
        let stride = 8 * 8 * 3;
        let (xs, ys) = slice_batch(&x, &y, 3..6).unwrap();
        assert_eq!(xs.shape, vec![3, 8, 8, 3]);
        assert_eq!(
            xs.as_f32().unwrap(),
            &x.as_f32().unwrap()[3 * stride..6 * stride]
        );
        let all_y = y_as_vec(&y);
        assert_eq!(y_as_vec(&ys), &all_y[3..6]);
        // out-of-range and empty slices are rejected
        assert!(slice_batch(&x, &y, 6..9).is_err());
        assert!(slice_batch(&x, &y, 4..4).is_err());
    }

    #[test]
    fn determinism_by_seed() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut a = Sampler::new(d.n, 8, AugmentCfg::default(), 9);
        let mut b = Sampler::new(d.n, 8, AugmentCfg::default(), 9);
        let (xa, _) = a.next_batch(&d);
        let (xb, _) = b.next_batch(&d);
        assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
    }
}
