//! Mini-batch sampling: per-epoch permutations (the standard
//! without-replacement protocol the paper's SMD analysis contrasts with)
//! plus standard CIFAR augmentation (4-px pad + random crop, horizontal
//! flip) applied on the fly in rust — never in the HLO.

use anyhow::{anyhow, bail, Result};

use crate::runtime::{HostTensor, TensorData};
use crate::util::Rng;

use super::Dataset;

#[derive(Debug, Clone, Copy)]
pub struct AugmentCfg {
    pub pad: usize,
    pub flip: bool,
    pub enabled: bool,
}

impl Default for AugmentCfg {
    fn default() -> Self {
        Self { pad: 4, flip: true, enabled: true }
    }
}

/// Exported sampler position (`checkpoint` subsystem): the RNG stream,
/// the current epoch's permutation, and the cursor into it — everything
/// `next_batch` consumes that isn't the dataset itself.  Restoring one
/// mid-stream continues the batch/augmentation sequence bitwise.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplerState {
    /// xoshiro256** state ([`Rng::state`]).
    pub rng: [u64; 4],
    /// Current epoch permutation (u32 is ample: datasets are indexed in
    /// memory, far below 2^32 samples).
    pub perm: Vec<u32>,
    pub cursor: u64,
    pub epoch: u64,
}

/// Deterministic batch sampler over a dataset.
pub struct Sampler {
    rng: Rng,
    perm: Vec<usize>,
    cursor: usize,
    pub epoch: u64,
    batch: usize,
    augment: AugmentCfg,
}

impl Sampler {
    pub fn new(dataset_len: usize, batch: usize, augment: AugmentCfg, seed: u64) -> Self {
        let mut s = Self {
            rng: Rng::seed_from_u64(seed),
            perm: (0..dataset_len).collect(),
            cursor: 0,
            epoch: 0,
            batch,
            augment,
        };
        s.shuffle();
        s
    }

    fn shuffle(&mut self) {
        let mut rng = self.rng.clone();
        rng.shuffle(&mut self.perm);
        self.rng = rng;
        self.cursor = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.perm.len() / self.batch
    }

    /// The RNG draws for one sample's augmentation — shared by
    /// [`Sampler::next_batch`] and [`Sampler::skip_batch`] so a shadow
    /// cursor consumes draw-for-draw the identical stream.
    fn draw_augment(&mut self) -> (isize, isize, bool) {
        let pad = self.augment.pad as isize;
        let dy = self.rng.offset(pad);
        let dx = self.rng.offset(pad);
        let flip = self.augment.flip && self.rng.bool(0.5);
        (dy, dx, flip)
    }

    /// Next batch of (x, y) host tensors; reshuffles between epochs.
    pub fn next_batch(&mut self, data: &Dataset) -> (HostTensor, HostTensor) {
        if self.cursor + self.batch > self.perm.len() {
            self.epoch += 1;
            self.shuffle();
        }
        let hw = data.hw;
        let stride = hw * hw * 3;
        let mut x = vec![0f32; self.batch * stride];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            let idx = self.perm[self.cursor + b];
            y[b] = data.labels[idx];
            let src = &data.images[idx * stride..(idx + 1) * stride];
            let dst = &mut x[b * stride..(b + 1) * stride];
            if self.augment.enabled {
                let (dy, dx, flip) = self.draw_augment();
                crop_flip(src, dst, hw, dy, dx, flip);
            } else {
                dst.copy_from_slice(src);
            }
        }
        self.cursor += self.batch;
        (
            HostTensor::f32(vec![self.batch, hw, hw, 3], x),
            HostTensor::i32(vec![self.batch], y),
        )
    }

    /// Consume one batch's worth of cursor/RNG state without assembling
    /// tensors — draw-for-draw identical to [`Sampler::next_batch`].
    /// The trainer's *shadow cursor* tracks the prefetch worker's
    /// sampler with this (3 cheap draws per sample, no pixel work), so
    /// a checkpoint can export the exact stream position at the step
    /// loop's consumption point even though the live sampler runs ahead
    /// on another thread.
    pub fn skip_batch(&mut self) {
        if self.cursor + self.batch > self.perm.len() {
            self.epoch += 1;
            self.shuffle();
        }
        if self.augment.enabled {
            for _ in 0..self.batch {
                let _ = self.draw_augment();
            }
        }
        self.cursor += self.batch;
    }

    /// Export the stream position for a checkpoint.
    pub fn export(&self) -> SamplerState {
        SamplerState {
            rng: self.rng.state(),
            perm: self.perm.iter().map(|&p| p as u32).collect(),
            cursor: self.cursor as u64,
            epoch: self.epoch,
        }
    }

    /// Rebuild a sampler mid-stream from an exported state.  Validates
    /// hard — the permutation must cover `0..dataset_len` exactly, the
    /// cursor must be in range, the RNG state must be live — so a
    /// corrupt checkpoint surfaces here as a clean error instead of an
    /// out-of-bounds panic inside `next_batch`.
    pub fn restore(
        st: &SamplerState,
        dataset_len: usize,
        batch: usize,
        augment: AugmentCfg,
    ) -> Result<Self> {
        if batch == 0 {
            bail!("sampler batch size must be positive");
        }
        if st.perm.len() != dataset_len {
            bail!(
                "sampler state covers {} samples, dataset has {dataset_len}",
                st.perm.len()
            );
        }
        let mut seen = vec![false; dataset_len];
        for &p in &st.perm {
            let p = p as usize;
            if p >= dataset_len || seen[p] {
                bail!("sampler state permutation is corrupt");
            }
            seen[p] = true;
        }
        if st.cursor as usize > dataset_len {
            bail!(
                "sampler cursor {} out of range for {dataset_len} samples",
                st.cursor
            );
        }
        let rng = Rng::from_state(st.rng)
            .ok_or_else(|| anyhow!("sampler RNG state is corrupt (all zero)"))?;
        Ok(Self {
            rng,
            perm: st.perm.iter().map(|&p| p as usize).collect(),
            cursor: st.cursor as usize,
            epoch: st.epoch,
            batch,
            augment,
        })
    }
}

/// Contiguous per-shard row ranges covering `0..n`: up to `shards`
/// non-empty ranges whose sizes differ by at most one (the leading
/// ranges absorb the remainder of a non-divisible split).  Concatenated
/// in order they reproduce the original batch exactly, which is what
/// keeps the sharded reduction's sample order — and therefore its
/// floats — identical to the single-device pass (`runtime::shard`).
pub fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let s = shards.max(1).min(n);
    let base = n / s;
    let rem = n % s;
    let mut out = Vec::with_capacity(s);
    let mut lo = 0;
    for i in 0..s {
        let len = base + usize::from(i < rem);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

/// Slice rows `range` out of an assembled `(x, y)` batch — the
/// per-shard view of one training batch.  Row payloads are copied
/// verbatim (augmentation already happened upstream in the sampler /
/// prefetch worker), so shard slicing never perturbs the batch stream.
pub fn slice_batch(
    x: &HostTensor,
    y: &HostTensor,
    range: std::ops::Range<usize>,
) -> Result<(HostTensor, HostTensor)> {
    let b = x.shape.first().copied().unwrap_or(0);
    if range.start >= range.end || range.end > b {
        bail!("shard slice {range:?} out of range for batch of {b}");
    }
    let stride: usize = x.shape[1..].iter().product();
    let xs = x.as_f32()?;
    let ys = match &y.data {
        TensorData::I32(v) => v,
        _ => bail!("labels must be i32"),
    };
    if ys.len() != b {
        bail!("labels hold {} rows, batch has {b}", ys.len());
    }
    let mut shape = x.shape.clone();
    shape[0] = range.len();
    Ok((
        HostTensor::f32(
            shape,
            xs[range.start * stride..range.end * stride].to_vec(),
        ),
        HostTensor::i32(vec![range.len()], ys[range].to_vec()),
    ))
}

/// Shift-crop with zero padding + optional horizontal flip (HWC layout).
fn crop_flip(src: &[f32], dst: &mut [f32], hw: usize, dy: isize, dx: isize, flip: bool) {
    for yy in 0..hw {
        for xx in 0..hw {
            let sy = yy as isize + dy;
            let sx_raw = xx as isize + dx;
            let sx = if flip { hw as isize - 1 - sx_raw } else { sx_raw };
            let d = (yy * hw + xx) * 3;
            if sy >= 0 && sy < hw as isize && sx >= 0 && sx < hw as isize {
                let s = (sy as usize * hw + sx as usize) * 3;
                dst[d..d + 3].copy_from_slice(&src[s..s + 3]);
            } else {
                dst[d..d + 3].fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn batches_cover_epoch_without_replacement() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut s = Sampler::new(
            d.n,
            16,
            AugmentCfg { enabled: false, ..Default::default() },
            1,
        );
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let (_, y) = s.next_batch(&d);
            for v in y_as_vec(&y) {
                seen.insert(v);
            }
        }
        // 64 samples / 10 classes: all classes seen in one epoch.
        assert_eq!(seen.len(), 10);
        assert_eq!(s.epoch, 0);
        let _ = s.next_batch(&d);
        assert_eq!(s.epoch, 1);
    }

    fn y_as_vec(t: &HostTensor) -> Vec<i32> {
        match &t.data {
            crate::runtime::TensorData::I32(v) => v.clone(),
            _ => panic!(),
        }
    }

    #[test]
    fn augmentation_changes_pixels_not_labels() {
        let d = synthetic::generate(10, 32, 8, 0);
        let mut s1 = Sampler::new(d.n, 32, AugmentCfg::default(), 3);
        let mut s2 = Sampler::new(
            d.n,
            32,
            AugmentCfg { enabled: false, ..Default::default() },
            3,
        );
        let (x1, y1) = s1.next_batch(&d);
        let (x2, y2) = s2.next_batch(&d);
        assert_eq!(y_as_vec(&y1), y_as_vec(&y2));
        assert_ne!(x1.as_f32().unwrap(), x2.as_f32().unwrap());
    }

    #[test]
    fn crop_zero_shift_is_identity() {
        let src: Vec<f32> = (0..4 * 4 * 3).map(|i| i as f32).collect();
        let mut dst = vec![0f32; src.len()];
        crop_flip(&src, &mut dst, 4, 0, 0, false);
        assert_eq!(src, dst);
    }

    #[test]
    fn flip_reverses_rows() {
        let src: Vec<f32> = (0..2 * 2 * 3).map(|i| i as f32).collect();
        let mut dst = vec![0f32; src.len()];
        crop_flip(&src, &mut dst, 2, 0, 0, true);
        // pixel (0,0) <- (0,1)
        assert_eq!(dst[0..3], src[3..6]);
        assert_eq!(dst[3..6], src[0..3]);
    }

    #[test]
    fn shard_ranges_cover_and_balance() {
        assert_eq!(shard_ranges(8, 1), vec![0..8]);
        assert_eq!(shard_ranges(8, 2), vec![0..4, 4..8]);
        // non-divisible: leading shards take the remainder
        assert_eq!(shard_ranges(8, 3), vec![0..3, 3..6, 6..8]);
        // more shards than rows: only non-empty ranges come back
        assert_eq!(shard_ranges(2, 5), vec![0..1, 1..2]);
        assert!(shard_ranges(0, 4).is_empty());
        // concatenation always reproduces 0..n
        for (n, s) in [(7, 3), (16, 5), (9, 9), (10, 1)] {
            let rs = shard_ranges(n, s);
            let mut lo = 0;
            for r in &rs {
                assert_eq!(r.start, lo);
                lo = r.end;
            }
            assert_eq!(lo, n);
        }
    }

    #[test]
    fn slice_batch_preserves_rows() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut s = Sampler::new(d.n, 8, AugmentCfg::default(), 2);
        let (x, y) = s.next_batch(&d);
        let stride = 8 * 8 * 3;
        let (xs, ys) = slice_batch(&x, &y, 3..6).unwrap();
        assert_eq!(xs.shape, vec![3, 8, 8, 3]);
        assert_eq!(
            xs.as_f32().unwrap(),
            &x.as_f32().unwrap()[3 * stride..6 * stride]
        );
        let all_y = y_as_vec(&y);
        assert_eq!(y_as_vec(&ys), &all_y[3..6]);
        // out-of-range and empty slices are rejected
        assert!(slice_batch(&x, &y, 6..9).is_err());
        assert!(slice_batch(&x, &y, 4..4).is_err());
    }

    #[test]
    fn skip_batch_is_draw_identical_to_next_batch() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut real = Sampler::new(d.n, 8, AugmentCfg::default(), 13);
        let mut shadow = Sampler::new(d.n, 8, AugmentCfg::default(), 13);
        // Cross an epoch boundary (64/8 = 8 batches/epoch).
        for _ in 0..11 {
            let _ = real.next_batch(&d);
            shadow.skip_batch();
        }
        assert_eq!(real.export(), shadow.export());
        // ...and with augmentation off (no per-sample draws at all).
        let off = AugmentCfg { enabled: false, ..Default::default() };
        let mut real = Sampler::new(d.n, 8, off, 13);
        let mut shadow = Sampler::new(d.n, 8, off, 13);
        for _ in 0..11 {
            let _ = real.next_batch(&d);
            shadow.skip_batch();
        }
        assert_eq!(real.export(), shadow.export());
    }

    #[test]
    fn export_restore_continues_stream_bitwise() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut a = Sampler::new(d.n, 8, AugmentCfg::default(), 21);
        for _ in 0..5 {
            let _ = a.next_batch(&d);
        }
        let st = a.export();
        let mut b = Sampler::restore(&st, d.n, 8, AugmentCfg::default()).unwrap();
        for _ in 0..10 {
            let (xa, ya) = a.next_batch(&d);
            let (xb, yb) = b.next_batch(&d);
            assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
            assert_eq!(y_as_vec(&ya), y_as_vec(&yb));
        }
    }

    #[test]
    fn restore_rejects_corruption() {
        let d = synthetic::generate(10, 64, 8, 0);
        let s = Sampler::new(d.n, 8, AugmentCfg::default(), 3);
        let good = s.export();

        // wrong dataset length
        assert!(Sampler::restore(&good, d.n + 1, 8, AugmentCfg::default()).is_err());
        // duplicate permutation entry
        let mut dup = good.clone();
        dup.perm[1] = dup.perm[0];
        assert!(Sampler::restore(&dup, d.n, 8, AugmentCfg::default()).is_err());
        // out-of-range entry
        let mut oob = good.clone();
        oob.perm[0] = d.n as u32;
        assert!(Sampler::restore(&oob, d.n, 8, AugmentCfg::default()).is_err());
        // cursor past the end
        let mut cur = good.clone();
        cur.cursor = d.n as u64 + 1;
        assert!(Sampler::restore(&cur, d.n, 8, AugmentCfg::default()).is_err());
        // dead RNG
        let mut rng = good.clone();
        rng.rng = [0; 4];
        assert!(Sampler::restore(&rng, d.n, 8, AugmentCfg::default()).is_err());
        // zero batch
        assert!(Sampler::restore(&good, d.n, 0, AugmentCfg::default()).is_err());
        // the untouched state restores fine
        assert!(Sampler::restore(&good, d.n, 8, AugmentCfg::default()).is_ok());
    }

    #[test]
    fn determinism_by_seed() {
        let d = synthetic::generate(10, 64, 8, 0);
        let mut a = Sampler::new(d.n, 8, AugmentCfg::default(), 9);
        let mut b = Sampler::new(d.n, 8, AugmentCfg::default(), 9);
        let (xa, _) = a.next_batch(&d);
        let (xb, _) = b.next_batch(&d);
        assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
    }
}
