//! Background batch prefetching: augmentation (crop/flip) and batch
//! assembly run on a worker thread, double-buffered through a bounded
//! channel, so data preparation overlaps executable dispatch.  An
//! SMD-dropped iteration (Sec. 3.1) consumes its prefetched batch
//! without stalling the step loop — the worker has the next one staged.
//!
//! Determinism: the worker owns a [`Sampler`] seeded exactly like the
//! synchronous path, so the batch *stream* is identical batch-for-batch
//! to `Sampler::next_batch` with the same seed (tested in
//! tests/resident_equivalence.rs).  The worker runs at most
//! `depth` batches ahead; it never reorders.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::obs::{self, Obs};
use crate::runtime::HostTensor;
use crate::util::fault::{self, FaultPlan, InjectedFault};

use super::sampler::{AugmentCfg, Sampler, SamplerState};
use super::Dataset;

/// Default channel depth: one batch in flight + one staged.
pub const DEFAULT_DEPTH: usize = 2;

/// Deepest channel the auto-tuner will pick: beyond this the staged
/// batches only cost memory — the worker can't get further ahead than
/// the channel bound anyway.
pub const MAX_DEPTH: usize = 8;

/// Channel depth for a measured augment-time / step-time ratio.
///
/// The worker needs roughly `ceil(augment/step)` batches of slack to
/// never stall the step loop, plus one in flight.  A fast augmenter
/// (ratio <= 1, the common case) lands on the classic double buffer;
/// a slow one gets more runway, capped at [`MAX_DEPTH`].  Degenerate
/// measurements (zero/NaN step time) fall back to [`DEFAULT_DEPTH`].
pub fn auto_depth(augment_mean_s: f64, step_mean_s: f64) -> usize {
    if !(step_mean_s > 0.0) || !augment_mean_s.is_finite() || augment_mean_s < 0.0 {
        return DEFAULT_DEPTH;
    }
    let ratio = augment_mean_s / step_mean_s;
    ((ratio.ceil() as usize) + 1).clamp(DEFAULT_DEPTH, MAX_DEPTH)
}

/// A background sampler producing an endless, deterministic batch
/// stream (reshuffling between epochs like [`Sampler`]).
pub struct Prefetcher {
    rx: Option<Receiver<(HostTensor, HostTensor)>>,
    worker: Option<JoinHandle<()>>,
    /// Set by the worker before exiting on a failed deferred dataset
    /// load, so the consumer's [`Prefetcher::next_batch`] surfaces the
    /// real cause instead of a generic worker-died error.
    error: Arc<Mutex<Option<anyhow::Error>>>,
    /// Observability handle: the worker times augmentation
    /// (`augment` spans on the prefetch thread), the consumer times the
    /// channel receive (`prefetch-stall` spans) and samples channel
    /// occupancy.  `Obs::off()` unless the trainer attached a hub.
    obs: Obs,
    /// Batches the worker has pushed into the channel (shared with the
    /// consumer for occupancy sampling).
    produced: Arc<AtomicU64>,
    /// Batches this consumer has pulled out.
    consumed: u64,
}

impl Prefetcher {
    pub fn spawn(
        data: Arc<Dataset>,
        batch: usize,
        augment: AugmentCfg,
        seed: u64,
        depth: usize,
    ) -> Result<Self> {
        Self::spawn_from(Sampler::new(data.n, batch, augment, seed), data, depth)
    }

    /// Spawn with a **deferred dataset**: `load` runs on the worker
    /// thread before the first batch, so decode (e.g. streaming the
    /// CIFAR binaries, `data::cifar::CifarFiles::decode`) overlaps the
    /// trainer's own setup and the main thread never materializes the
    /// training set.  The worker builds the sampler from the decoded
    /// dataset with the given seed, so the batch stream is bit-identical
    /// to a synchronous `Sampler` over an eager load.  A failed load
    /// ends the worker and the error comes back from the consumer's
    /// next [`Prefetcher::next_batch`].
    pub fn spawn_deferred<F>(
        load: F,
        batch: usize,
        augment: AugmentCfg,
        seed: u64,
        depth: usize,
    ) -> Result<Self>
    where
        F: FnOnce() -> Result<Dataset> + Send + 'static,
    {
        Self::spawn_deferred_opts(load, batch, augment, seed, depth, None, Obs::off())
    }

    /// [`Prefetcher::spawn_deferred`] with an optional fault plan (the
    /// `data.prefetch` site panics the worker mid-stream) and an
    /// observability handle.
    pub fn spawn_deferred_opts<F>(
        load: F,
        batch: usize,
        augment: AugmentCfg,
        seed: u64,
        depth: usize,
        faults: Option<Arc<FaultPlan>>,
        obs: Obs,
    ) -> Result<Self>
    where
        F: FnOnce() -> Result<Dataset> + Send + 'static,
    {
        Self::spawn_deferred_inner(
            load,
            depth,
            move |n| Ok(Sampler::new(n, batch, augment, seed)),
            faults,
            obs,
        )
    }

    /// Deferred-dataset spawn that **resumes** the stream: the worker
    /// rebuilds its sampler from an exported [`SamplerState`] instead of
    /// a fresh seed.  This is the checkpoint/resume path for streaming
    /// CIFAR-bin ingestion, where the sampler lives on this worker —
    /// the restored stream continues batch-for-batch where the
    /// checkpointed run's consumption point stood.  A state that does
    /// not match the decoded dataset fails like a failed load: the
    /// error surfaces from the consumer's next [`Prefetcher::next_batch`].
    pub fn spawn_deferred_resume<F>(
        load: F,
        batch: usize,
        augment: AugmentCfg,
        state: SamplerState,
        depth: usize,
    ) -> Result<Self>
    where
        F: FnOnce() -> Result<Dataset> + Send + 'static,
    {
        Self::spawn_deferred_resume_opts(
            load,
            batch,
            augment,
            state,
            depth,
            None,
            Obs::off(),
        )
    }

    /// [`Prefetcher::spawn_deferred_resume`] with an optional fault plan
    /// and an observability handle.
    pub fn spawn_deferred_resume_opts<F>(
        load: F,
        batch: usize,
        augment: AugmentCfg,
        state: SamplerState,
        depth: usize,
        faults: Option<Arc<FaultPlan>>,
        obs: Obs,
    ) -> Result<Self>
    where
        F: FnOnce() -> Result<Dataset> + Send + 'static,
    {
        Self::spawn_deferred_inner(
            load,
            depth,
            move |n| Sampler::restore(&state, n, batch, augment),
            faults,
            obs,
        )
    }

    fn spawn_deferred_inner<F, M>(
        load: F,
        depth: usize,
        make_sampler: M,
        faults: Option<Arc<FaultPlan>>,
        obs: Obs,
    ) -> Result<Self>
    where
        F: FnOnce() -> Result<Dataset> + Send + 'static,
        M: FnOnce(usize) -> Result<Sampler> + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth.max(1));
        let error = Arc::new(Mutex::new(None));
        let err_slot = error.clone();
        let produced = Arc::new(AtomicU64::new(0));
        let w_obs = obs.clone();
        let w_produced = produced.clone();
        let worker = std::thread::Builder::new()
            .name("e2train-prefetch".into())
            .spawn(move || {
                let data = match load() {
                    Ok(d) => Arc::new(d),
                    Err(e) => {
                        park(&err_slot, e);
                        return;
                    }
                };
                let sampler = match make_sampler(data.n) {
                    Ok(s) => s,
                    Err(e) => {
                        park(&err_slot, e);
                        return;
                    }
                };
                produce(sampler, data, tx, &err_slot, faults, w_obs, &w_produced);
            })
            .context("spawning prefetch thread")?;
        Ok(Self {
            rx: Some(rx),
            worker: Some(worker),
            error,
            obs,
            produced,
            consumed: 0,
        })
    }

    /// Spawn from an already-built (possibly partially-consumed)
    /// sampler.  This is the auto-tuning handoff: the trainer times a
    /// couple of probe batches synchronously on the real sampler,
    /// picks a depth ([`auto_depth`]), and hands the sampler over —
    /// the worker continues the exact same deterministic stream.
    pub fn spawn_from(
        sampler: Sampler,
        data: Arc<Dataset>,
        depth: usize,
    ) -> Result<Self> {
        Self::spawn_from_opts(sampler, data, depth, None, Obs::off())
    }

    /// [`Prefetcher::spawn_from`] with an optional fault plan and an
    /// observability handle.
    pub fn spawn_from_opts(
        sampler: Sampler,
        data: Arc<Dataset>,
        depth: usize,
        faults: Option<Arc<FaultPlan>>,
        obs: Obs,
    ) -> Result<Self> {
        let (tx, rx) = sync_channel(depth.max(1));
        let error = Arc::new(Mutex::new(None));
        let err_slot = error.clone();
        let produced = Arc::new(AtomicU64::new(0));
        let w_obs = obs.clone();
        let w_produced = produced.clone();
        let worker = std::thread::Builder::new()
            .name("e2train-prefetch".into())
            .spawn(move || {
                produce(sampler, data, tx, &err_slot, faults, w_obs, &w_produced)
            })
            .context("spawning prefetch thread")?;
        Ok(Self {
            rx: Some(rx),
            worker: Some(worker),
            error,
            obs,
            produced,
            consumed: 0,
        })
    }

    /// Blocking pull of the next staged batch (usually already
    /// buffered).  Errors when the worker stopped — with the deferred
    /// load's failure cause or the worker's panic message when there is
    /// one.
    pub fn next_batch(&mut self) -> Result<(HostTensor, HostTensor)> {
        let rx = self
            .rx
            .as_ref()
            .ok_or_else(|| anyhow!("prefetcher already shut down"))?;
        // Occupancy sample: batches staged ahead of this pull.  A pull
        // that finds the channel empty is a stall — the step loop is
        // about to block on data.
        let occ = self
            .produced
            .load(Ordering::Relaxed)
            .saturating_sub(self.consumed);
        self.obs.count(obs::CTR_PREFETCH_OCC_SUM, occ);
        self.obs.count(obs::CTR_PREFETCH_OCC_SAMPLES, 1);
        let t0 = Instant::now();
        let got = match rx.try_recv() {
            Ok(b) => Some(b),
            Err(TryRecvError::Empty) => {
                self.obs.count(obs::CTR_PREFETCH_STALLS, 1);
                rx.recv().ok()
            }
            Err(TryRecvError::Disconnected) => None,
        };
        // Always timed, not just on the empty path: the phase total
        // answers "how long did the step loop wait on data", which is
        // nonzero even when every batch was staged.
        self.obs.record(obs::PHASE_PREFETCH_STALL, t0.elapsed());
        match got {
            Some(b) => {
                self.consumed += 1;
                Ok(b)
            }
            None => Err(lock_err(&self.error)
                .take()
                .unwrap_or_else(|| anyhow!("prefetch worker died"))),
        }
    }
}

/// The worker's production loop.  Batch assembly runs under
/// `catch_unwind`, so an augment-path panic (or the injected
/// `data.prefetch` fault) lands in the error slot and flows out of
/// [`Prefetcher::next_batch`] as an error — it never poisons the slot
/// mutex or silently strands the consumer.
fn produce(
    mut sampler: Sampler,
    data: Arc<Dataset>,
    tx: SyncSender<(HostTensor, HostTensor)>,
    err_slot: &Mutex<Option<anyhow::Error>>,
    faults: Option<Arc<FaultPlan>>,
    obs: Obs,
    produced: &AtomicU64,
) {
    loop {
        let t0 = Instant::now();
        let made = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Some(p) = &faults {
                if p.hit(fault::SITE_PREFETCH).is_some() {
                    panic!("{}", InjectedFault::new(fault::SITE_PREFETCH));
                }
            }
            sampler.next_batch(&data)
        }));
        let b = match made {
            Ok(b) => b,
            Err(payload) => {
                park(
                    err_slot,
                    anyhow!(
                        "prefetch worker panicked assembling a batch: {}",
                        panic_message(&payload)
                    ),
                );
                return;
            }
        };
        // Recorded on this thread ("e2train-prefetch"), so augment time
        // stays distinguishable from the step loop's own phases.
        obs.record(obs::PHASE_AUGMENT, t0.elapsed());
        obs.count(obs::CTR_PREFETCH_PRODUCED, 1);
        // The receiver hung up: the run is over.
        if tx.send(b).is_err() {
            return;
        }
        produced.fetch_add(1, Ordering::Relaxed);
    }
}

/// Store an error for the consumer; a poisoned slot (a panic elsewhere
/// while holding the lock) must not eat the real cause.
fn park(slot: &Mutex<Option<anyhow::Error>>, e: anyhow::Error) {
    *lock_err(slot) = Some(e);
}

fn lock_err(
    slot: &Mutex<Option<anyhow::Error>>,
) -> std::sync::MutexGuard<'_, Option<anyhow::Error>> {
    slot.lock().unwrap_or_else(|p| p.into_inner())
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Hang up first so a worker blocked in send() unblocks, then
        // reap the thread.
        drop(self.rx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn stream_matches_synchronous_sampler() {
        let data = Arc::new(synthetic::generate(10, 64, 8, 0));
        let mut sync = Sampler::new(data.n, 16, AugmentCfg::default(), 42);
        let mut pre =
            Prefetcher::spawn(data.clone(), 16, AugmentCfg::default(), 42, 2).unwrap();
        for _ in 0..12 {
            // crosses an epoch boundary (reshuffle) at batch 4
            let (xa, ya) = sync.next_batch(&data);
            let (xb, yb) = pre.next_batch().unwrap();
            assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
            match (&ya.data, &yb.data) {
                (
                    crate::runtime::TensorData::I32(a),
                    crate::runtime::TensorData::I32(b),
                ) => assert_eq!(a, b),
                _ => panic!("labels must be i32"),
            }
        }
    }

    #[test]
    fn spawn_from_continues_a_consumed_sampler() {
        let data = Arc::new(synthetic::generate(10, 64, 8, 0));
        let mut sync = Sampler::new(data.n, 16, AugmentCfg::default(), 7);
        let mut handoff = Sampler::new(data.n, 16, AugmentCfg::default(), 7);
        // Probe phase consumes two batches synchronously...
        let _ = handoff.next_batch(&data);
        let _ = handoff.next_batch(&data);
        let mut pre = Prefetcher::spawn_from(handoff, data.clone(), 3).unwrap();
        // ...and the worker must continue at batch 2 of the same stream.
        let _ = sync.next_batch(&data);
        let _ = sync.next_batch(&data);
        for _ in 0..6 {
            let (xa, _) = sync.next_batch(&data);
            let (xb, _) = pre.next_batch().unwrap();
            assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
        }
    }

    #[test]
    fn auto_depth_tracks_the_ratio() {
        // fast augmenter -> double buffer
        assert_eq!(auto_depth(0.1e-3, 1.0e-3), DEFAULT_DEPTH);
        assert_eq!(auto_depth(1.0e-3, 1.0e-3), DEFAULT_DEPTH);
        // augmentation ~3x the step -> 4 staged batches
        assert_eq!(auto_depth(3.0e-3, 1.0e-3), 4);
        // pathological ratios clamp
        assert_eq!(auto_depth(1.0, 1.0e-6), MAX_DEPTH);
        // degenerate measurements fall back
        assert_eq!(auto_depth(1.0e-3, 0.0), DEFAULT_DEPTH);
        assert_eq!(auto_depth(f64::NAN, 1.0e-3), DEFAULT_DEPTH);
        assert_eq!(auto_depth(1.0e-3, f64::NAN), DEFAULT_DEPTH);
    }

    #[test]
    fn deferred_spawn_matches_synchronous_sampler() {
        let sync_data = synthetic::generate(10, 64, 8, 3);
        let mut sync = Sampler::new(sync_data.n, 16, AugmentCfg::default(), 11);
        let mut pre = Prefetcher::spawn_deferred(
            || Ok(synthetic::generate(10, 64, 8, 3)),
            16,
            AugmentCfg::default(),
            11,
            2,
        )
        .unwrap();
        for _ in 0..6 {
            let (xa, _) = sync.next_batch(&sync_data);
            let (xb, _) = pre.next_batch().unwrap();
            assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
        }
    }

    #[test]
    fn deferred_resume_continues_the_stream() {
        let data = Arc::new(synthetic::generate(10, 64, 8, 5));
        // Ground truth: one uninterrupted synchronous stream.
        let mut sync = Sampler::new(data.n, 16, AugmentCfg::default(), 17);
        // Interrupted stream: consume 3 batches, export, resume on a
        // deferred worker over a freshly-decoded dataset.
        let mut first = Sampler::new(data.n, 16, AugmentCfg::default(), 17);
        for _ in 0..3 {
            let _ = sync.next_batch(&data);
            let _ = first.next_batch(&data);
        }
        let state = first.export();
        let mut pre = Prefetcher::spawn_deferred_resume(
            || Ok(synthetic::generate(10, 64, 8, 5)),
            16,
            AugmentCfg::default(),
            state,
            2,
        )
        .unwrap();
        for _ in 0..8 {
            let (xa, _) = sync.next_batch(&data);
            let (xb, _) = pre.next_batch().unwrap();
            assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
        }
    }

    #[test]
    fn deferred_resume_rejects_mismatched_state() {
        let data = synthetic::generate(10, 64, 8, 5);
        let state = Sampler::new(data.n, 16, AugmentCfg::default(), 0).export();
        // worker decodes a dataset of a different size -> clean error
        let mut pre = Prefetcher::spawn_deferred_resume(
            || Ok(synthetic::generate(10, 32, 8, 5)),
            16,
            AugmentCfg::default(),
            state,
            2,
        )
        .unwrap();
        let err = pre.next_batch().unwrap_err();
        assert!(format!("{err:#}").contains("dataset has"), "lost the cause");
    }

    #[test]
    fn deferred_load_failure_surfaces_the_error() {
        let mut pre = Prefetcher::spawn_deferred(
            || Err(anyhow!("boom: dataset went missing")),
            8,
            AugmentCfg::default(),
            0,
            2,
        )
        .unwrap();
        let err = pre.next_batch().unwrap_err();
        assert!(format!("{err:#}").contains("boom"), "lost the load error");
    }

    #[test]
    fn drop_mid_stream_terminates_worker() {
        let data = Arc::new(synthetic::generate(4, 32, 4, 1));
        let mut pre = Prefetcher::spawn(data, 8, AugmentCfg::default(), 0, 2).unwrap();
        let _ = pre.next_batch().unwrap();
        drop(pre); // must not hang
    }

    /// A worker panic mid-stream (here: the injected `data.prefetch`
    /// fault) surfaces from `next_batch` as an error carrying the panic
    /// message — batches before the panic are unaffected, the slot
    /// mutex never poisons, and drop still reaps the thread.
    #[test]
    fn worker_panic_surfaces_as_an_error() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let data = Arc::new(synthetic::generate(10, 64, 8, 0));
        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_PREFETCH.into(),
                    at: 3,
                    times: 1,
                    after_bytes: None,
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let sampler = Sampler::new(data.n, 16, AugmentCfg::default(), 9);
        let mut pre =
            Prefetcher::spawn_from_opts(sampler, data, 2, Some(plan), Obs::off())
                .unwrap();
        // batches 1 and 2 stream normally
        assert!(pre.next_batch().is_ok());
        assert!(pre.next_batch().is_ok());
        // batch 3 panicked on the worker -> typed message, not a hang
        let err = pre.next_batch().unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("panicked") && msg.contains(fault::SITE_PREFETCH),
            "unexpected error: {msg}"
        );
        // the prefetcher stays usable as an object (errors, not panics)
        assert!(pre.next_batch().is_err());
    }
}
