//! Background batch prefetching: augmentation (crop/flip) and batch
//! assembly run on a worker thread, double-buffered through a bounded
//! channel, so data preparation overlaps executable dispatch.  An
//! SMD-dropped iteration (Sec. 3.1) consumes its prefetched batch
//! without stalling the step loop — the worker has the next one staged.
//!
//! Determinism: the worker owns a [`Sampler`] seeded exactly like the
//! synchronous path, so the batch *stream* is identical batch-for-batch
//! to `Sampler::next_batch` with the same seed (tested in
//! tests/resident_equivalence.rs).  The worker runs at most
//! `depth` batches ahead; it never reorders.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::runtime::HostTensor;

use super::sampler::{AugmentCfg, Sampler};
use super::Dataset;

/// Default channel depth: one batch in flight + one staged.
pub const DEFAULT_DEPTH: usize = 2;

/// A background sampler producing an endless, deterministic batch
/// stream (reshuffling between epochs like [`Sampler`]).
pub struct Prefetcher {
    rx: Option<Receiver<(HostTensor, HostTensor)>>,
    worker: Option<JoinHandle<()>>,
}

impl Prefetcher {
    pub fn spawn(
        data: Arc<Dataset>,
        batch: usize,
        augment: AugmentCfg,
        seed: u64,
        depth: usize,
    ) -> Self {
        let (tx, rx) = sync_channel(depth.max(1));
        let worker = std::thread::Builder::new()
            .name("e2train-prefetch".into())
            .spawn(move || {
                let mut sampler = Sampler::new(data.n, batch, augment, seed);
                loop {
                    let b = sampler.next_batch(&data);
                    // The receiver hung up: the run is over.
                    if tx.send(b).is_err() {
                        return;
                    }
                }
            })
            .expect("spawning prefetch thread");
        Self { rx: Some(rx), worker: Some(worker) }
    }

    /// Blocking pull of the next staged batch (usually already buffered).
    pub fn next_batch(&mut self) -> (HostTensor, HostTensor) {
        self.rx
            .as_ref()
            .expect("prefetcher already shut down")
            .recv()
            .expect("prefetch worker died")
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Hang up first so a worker blocked in send() unblocks, then
        // reap the thread.
        drop(self.rx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn stream_matches_synchronous_sampler() {
        let data = Arc::new(synthetic::generate(10, 64, 8, 0));
        let mut sync = Sampler::new(data.n, 16, AugmentCfg::default(), 42);
        let mut pre = Prefetcher::spawn(data.clone(), 16, AugmentCfg::default(), 42, 2);
        for _ in 0..12 {
            // crosses an epoch boundary (reshuffle) at batch 4
            let (xa, ya) = sync.next_batch(&data);
            let (xb, yb) = pre.next_batch();
            assert_eq!(xa.as_f32().unwrap(), xb.as_f32().unwrap());
            match (&ya.data, &yb.data) {
                (
                    crate::runtime::TensorData::I32(a),
                    crate::runtime::TensorData::I32(b),
                ) => assert_eq!(a, b),
                _ => panic!("labels must be i32"),
            }
        }
    }

    #[test]
    fn drop_mid_stream_terminates_worker() {
        let data = Arc::new(synthetic::generate(4, 32, 4, 1));
        let mut pre = Prefetcher::spawn(data, 8, AugmentCfg::default(), 0, 2);
        let _ = pre.next_batch();
        drop(pre); // must not hang
    }
}
