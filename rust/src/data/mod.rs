//! Data substrate: dataset container, procedural CIFAR-like generator
//! (the offline substitute for CIFAR-10/100 — DESIGN.md §Substitutions),
//! real CIFAR-10 binary loader, and the augmenting mini-batch sampler.

pub mod cifar;
pub mod prefetch;
pub mod sampler;
pub mod synthetic;

pub use prefetch::Prefetcher;
pub use sampler::{shard_ranges, slice_batch, AugmentCfg, Sampler, SamplerState};

/// An in-memory image-classification dataset, NHWC f32 + i32 labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// n * hw * hw * 3 pixel values (normalized).
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub hw: usize,
    pub classes: usize,
}

impl Dataset {
    /// Split off the first `frac` of samples (the Sec. 4.5 fine-tuning
    /// experiment splits each class i.i.d.; with shuffled synthetic data
    /// a prefix split is i.i.d. by construction).
    pub fn split(&self, frac: f64) -> (Dataset, Dataset) {
        let k = ((self.n as f64) * frac) as usize;
        let stride = self.hw * self.hw * 3;
        let a = Dataset {
            images: self.images[..k * stride].to_vec(),
            labels: self.labels[..k].to_vec(),
            n: k,
            hw: self.hw,
            classes: self.classes,
        };
        let b = Dataset {
            images: self.images[k * stride..].to_vec(),
            labels: self.labels[k..].to_vec(),
            n: self.n - k,
            hw: self.hw,
            classes: self.classes,
        };
        (a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_partitions() {
        let d = synthetic::generate(10, 100, 8, 0);
        let (a, b) = d.split(0.5);
        assert_eq!(a.n + b.n, d.n);
        assert_eq!(a.images.len() + b.images.len(), d.images.len());
        let mut rejoined = a.labels.clone();
        rejoined.extend(&b.labels);
        assert_eq!(rejoined, d.labels);
    }
}
