//! Minimal in-repo stand-in for the `anyhow` crate (the offline testbed
//! ships no crates.io registry).  Implements the subset this workspace
//! uses: `Error` with a context chain, `Result<T>`, the `anyhow!` /
//! `bail!` macros, and the `Context` extension trait on `Result` and
//! `Option`.
//!
//! Like real anyhow, `Error` deliberately does NOT implement
//! `std::error::Error`, which is what lets the blanket
//! `From<E: std::error::Error>` conversion coexist with the reflexive
//! `From<Error>`.

use std::fmt;

/// An error with an outermost-first context chain.
pub struct Error {
    /// `chain[0]` is the most recently attached context; the root cause
    /// is last.
    chain: Vec<String>,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { chain: vec![msg.to_string()] }
    }

    /// Attach an outer context message (what `.context(...)` does).
    pub fn push_context(mut self, ctx: impl fmt::Display) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_chain() {
        let e: Error = io_err().into();
        let e = e.push_context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("no such file"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with code 7");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }
}
