//! In-repo API stub for the `xla` crate (the offline testbed has no
//! crates.io registry and no PJRT shared library).
//!
//! The *data* surface — `Literal`, shapes, element types — is fully
//! functional and bit-exact, so everything that moves tensors across the
//! host boundary works.  The *execution* surface (`PjRtClient::compile` +
//! `PjRtLoadedExecutable::execute`) parses and accepts HLO text but
//! returns a clear error at execute time: there is no XLA runtime in this
//! build.  The e2train runtime treats that exactly like missing
//! artifacts and runs its pure-rust reference backend instead
//! (`e2train::runtime::reference`).  Swapping this path dependency for
//! the real `xla` crate restores PJRT execution without code changes.

use std::fmt;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

// ---------------------------------------------------------------------------
// Element types and shapes
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    F32,
    F64,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn new(dims: Vec<i64>, ty: ElementType) -> Self {
        Self { dims, ty }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

// ---------------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------------

/// Native types a literal can hold in this stub.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(payload: &Payload) -> Option<&[Self]>;
}

#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host literal: shape + typed storage (or a tuple of literals).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: Vec<i64>,
    payload: Payload,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Self {
        Self { shape: vec![], payload: T::wrap(vec![v]) }
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Self {
        Self { shape: vec![data.len() as i64], payload: T::wrap(data.to_vec()) }
    }

    pub fn tuple(parts: Vec<Literal>) -> Self {
        Self { shape: vec![], payload: Payload::Tuple(parts) }
    }

    fn stored_len(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.payload, Payload::Tuple(_)) {
            return err("cannot reshape a tuple literal");
        }
        if n.max(1) as usize != self.stored_len() {
            return err(format!(
                "reshape to {:?} ({} elems) from {} elems",
                dims,
                n,
                self.stored_len()
            ));
        }
        Ok(Literal { shape: dims.to_vec(), payload: self.payload.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => return err("tuple literal has no array shape"),
        };
        Ok(ArrayShape::new(self.shape.clone(), ty))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::unwrap(&self.payload) {
            Some(v) => Ok(v.to_vec()),
            None => err(format!(
                "literal holds {:?}, asked for {:?}",
                self.array_shape().map(|s| s.ty()),
                T::TY
            )),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(parts) => Ok(parts),
            // PJRT decomposes single-output programs transparently.
            _ => Ok(vec![self]),
        }
    }
}

// ---------------------------------------------------------------------------
// HLO artifacts
// ---------------------------------------------------------------------------

/// Parsed HLO module (text is retained verbatim; the stub performs only
/// surface validation).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        if text.trim().is_empty() {
            return err(format!("empty HLO text file {path}"));
        }
        Ok(Self { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { proto: proto.clone() }
    }
}

// ---------------------------------------------------------------------------
// PJRT client / executable (stubbed execution)
// ---------------------------------------------------------------------------

/// Device buffer handle.  In the stub it wraps a literal; the real crate
/// holds an opaque device allocation.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn from_literal(lit: Literal) -> Self {
        Self { lit }
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    /// Retained for diagnostics; the stub cannot interpret it.
    hlo_bytes: usize,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(format!(
            "this build has no PJRT runtime (stub xla crate; hlo {} bytes). \
             Use reference artifacts (*.ref.json) or link the real xla crate.",
            self.hlo_bytes
        ))
    }
}

/// PJRT client handle.  The stub is plain data and therefore Send+Sync,
/// which the parallel experiment fan-out relies on; the real crate's CPU
/// client is not Sync — see experiments::runs for the gating note.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { hlo_bytes: comp.proto.text.len() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let t = Literal::tuple(vec![Literal::scalar(1.5f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.5]);
        // non-tuple decomposes to itself
        let one = Literal::scalar(3i32).to_tuple().unwrap();
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn reshape_validates() {
        let l = Literal::vec1(&[0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn execute_is_stubbed() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: "HloModule m".into(),
        });
        let exe = client.compile(&comp).unwrap();
        let args = [Literal::scalar(1.0f32)];
        assert!(exe.execute::<Literal>(&args).is_err());
    }
}
