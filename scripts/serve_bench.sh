#!/usr/bin/env bash
# Launch the micro-batching inference service bench and record
# BENCH_serve.json (schema bench_serve/v1) at the repo root.
#
# Usage: scripts/serve_bench.sh [extra e2train serve flags...]
# e.g.:  scripts/serve_bench.sh --clients 2,8,32 --workers 4
#
# Release profile — serve latency percentiles are meaningless in debug.
set -euo pipefail
cd "$(dirname "$0")/.."

exec cargo run --release --bin e2train -- serve \
  --clients 2,8 \
  --requests 64 \
  --req-size 2 \
  --workers 2 \
  --delay-ms 2 \
  --out BENCH_serve.json \
  "$@"
