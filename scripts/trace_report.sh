#!/usr/bin/env bash
# Demo the observability plane end-to-end: run a short traced training
# run (reference family, checkpointing on so every instrumented layer
# fires), then render the obs_trace/v1 JSONL with `e2train trace-report`.
#
# Usage: scripts/trace_report.sh [extra e2train train flags...]
# e.g.:  scripts/trace_report.sh --backend sharded --shards 2
#
# Tracing is observability-plane only: the traced run is bitwise
# identical to the untraced one (tests/obs_invariance.rs).
set -euo pipefail
cd "$(dirname "$0")/.."

TRACE="${TRACE:-trace.jsonl}"
CKPT_DIR="$(mktemp -d)"
trap 'rm -rf "$CKPT_DIR"' EXIT

cargo run --release --bin e2train -- gen-ref
cargo run --release --bin e2train -- train \
  --family refmlp-tiny \
  --method sgd32 \
  --iters 60 \
  --ckpt-every 20 \
  --ckpt-dir "$CKPT_DIR" \
  --trace-out "$TRACE" \
  "$@"

exec cargo run --release --bin e2train -- trace-report "$TRACE"
