#!/usr/bin/env bash
# Demonstrates checkpoint replication & disaster recovery end-to-end on
# the reference backend:
#   1. materialize the reference artifact families,
#   2. train with durable checkpoints AND replication armed — every
#      published checkpoint is evacuated to a replica root (resumable
#      chunked transfer, verified before publish),
#   3. disaster: destroy the local registry entirely ("the training box
#      died"),
#   4. resume from the replica alone — bitwise identical to the
#      uninterrupted run,
#   5. serve straight from the replica in the other failure domain (no
#      local registry, hash+trailer-verified hot-loads).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${BIN:-cargo run --release --quiet --bin e2train --}
CKPT_DIR=${CKPT_DIR:-checkpoints/replica-demo}
REPLICA_DIR=${REPLICA_DIR:-replica/replica-demo}

$BIN gen-ref

echo "== train with checkpoints every 40 iters, evacuating to $REPLICA_DIR =="
# sgd32: the serve bench below resolves the family's sgd32 artifact, so
# the registry's state layout must match the served method.
$BIN train --family refmlp-tiny --method sgd32 --iters 120 \
  --ckpt-every 40 --ckpt-dir "$CKPT_DIR" --replicate "$REPLICA_DIR" \
  --out RUN_replicated.json

echo "== disaster: the local registry is gone =="
rm -rf "$CKPT_DIR"

echo "== resume from the replica alone (replica: $REPLICA_DIR) =="
$BIN resume --replica "$REPLICA_DIR" --out RUN_replica_resumed.json

echo "== serve from the replica (other failure domain, no local registry) =="
$BIN serve --family refmlp-tiny --replica "$REPLICA_DIR" \
  --clients 2,8 --requests 16 --out BENCH_serve_replica.json

echo "replica contents:"
cat "$REPLICA_DIR/MANIFEST.json"
