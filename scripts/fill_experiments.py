#!/usr/bin/env python3
"""Render results/*.json (from `e2train exp all`) into the EXPERIMENTS.md
results section, paper reference values inline."""
import json, sys, pathlib

R = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "results")

def load(name):
    p = R / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None

out = []
def w(s=""): out.append(s)

f = load("fig3a")
if f:
    w("### Fig. 3a — SMD vs SMB across energy ratios")
    w()
    w("Paper: SMD beats SMB by **+0.39%..+0.86%** at every matched ratio.")
    w()
    w("| energy ratio | SMB acc | SMD acc | Δ |")
    w("|---|---|---|---|")
    wins = 0
    for r in f["rows"]:
        d = (r["smd_acc"] - r["smb_acc"]) * 100
        wins += d > 0
        w(f"| {r['ratio']:.3f} | {r['smb_acc']*100:.2f}% | {r['smd_acc']*100:.2f}% | {d:+.2f}% |")
    w()
    w(f"Measured: SMD wins at {wins}/{len(f['rows'])} ratios.")
    w()

f = load("fig3b")
if f:
    w("### Fig. 3b — SMD vs SMB + tuned LR (equal 2/3 budget)")
    w()
    w("Paper: SMD keeps ≥ **+0.22%** over the best SMB learning rate.")
    w()
    smbs = [r for r in f["rows"] if r["method"] == "smb"]
    smd = [r for r in f["rows"] if r["method"] == "smd"][0]
    best = max(smbs, key=lambda r: r["acc"])
    w("| method | acc |")
    w("|---|---|")
    for r in smbs:
        w(f"| SMB lr0={r['lr0']:.2f} | {r['acc']*100:.2f}% |")
    w(f"| **SMD p=1/3** | **{smd['acc']*100:.2f}%** |")
    w()
    w(f"Measured Δ vs best SMB (lr0={best['lr0']:.2f}): {(smd['acc']-best['acc'])*100:+.2f}%.")
    w()

f = load("tab1")
if f:
    w("### Table 1 — SMD on other datasets/backbones (energy ratio 0.67)")
    w()
    w("Paper: C10/ResNet-110 92.75→93.05 (+0.30), C100/ResNet-74 71.11→71.37 (+0.26).")
    w()
    w("| workload | SMB | SMD | Δ |")
    w("|---|---|---|---|")
    for r in f["rows"]:
        d = (r["smd_acc"] - r["smb_acc"]) * 100
        w(f"| {r['workload']} | {r['smb_acc']*100:.2f}% | {r['smd_acc']*100:.2f}% | {d:+.2f}% |")
    w()

f = load("fig4")
if f:
    w("### Fig. 4 — SLU vs SD vs SLU+SMD")
    w()
    w(f"Paper: SLU above SD at every matched energy; SLU+SMD better still. Baseline (SMB) acc here: {f['baseline_acc']*100:.2f}%.")
    w()
    w("| α | skip | SLU acc (E/E₀) | SD acc (E/E₀) | SLU+SMD acc (E/E₀) |")
    w("|---|---|---|---|---|")
    for r in f["rows"]:
        w(f"| {r['alpha']} | {r['skip']*100:.0f}% | "
          f"{r['slu']['acc']*100:.2f}% ({r['slu']['ratio']:.2f}) | "
          f"{r['sd']['acc']*100:.2f}% ({r['sd']['ratio']:.2f}) | "
          f"{r['slu_smd']['acc']*100:.2f}% ({r['slu_smd']['ratio']:.2f}) |")
    w()

f = load("tab2")
if f:
    w("### Table 2 — precision ablation (SGD-32 / 8-bit / SignSGD / PSG)")
    w()
    w("Paper: 32b 93.52 | 8bit 93.24 (38.6% save) | SignSGD 92.54 | PSG 92.59 (63.3% save).")
    w()
    w("| method | acc | energy saving |")
    w("|---|---|---|")
    for r in f["rows"]:
        w(f"| {r['method']} | {r['acc']*100:.2f}% | {r['saving']*100:.1f}% |")
    w()

f = load("tab3")
if f:
    w("### Table 3 — E²-Train skipping/threshold sweep")
    w()
    w("Paper (β=.05): skip 20/40/60% → acc 92.12/91.84/91.36, energy save 84.6/88.7/92.8%.")
    w()
    w("| β | α | skip | acc | comp. saving | energy saving |")
    w("|---|---|---|---|---|---|")
    for r in f["rows"]:
        w(f"| {r['beta']} | {r['alpha']} | {r['skip']*100:.0f}% | {r['acc']*100:.2f}% "
          f"| {r['comp_saving']*100:.1f}% | {r['energy_saving']*100:.1f}% |")
    w()

f = load("fig5")
if f:
    w("### Fig. 5 — convergence: test accuracy vs cumulative energy")
    w()
    w("Paper: E²-Train converges at least as fast per joule.")
    w()
    for c in f["curves"]:
        pts = "  ".join(f"{j:.2f}J→{a*100:.0f}%" for j, a in c["points"])
        w(f"- **{c['label']}** (final {c['final_acc']*100:.2f}%): {pts}")
    w()

f = load("tab4")
if f:
    w("### Table 4 — other backbones/datasets")
    w()
    w("Paper: e.g. C10/ResNet-110 E²-Train 83.4% saving at −0.56% acc; MobileNetV2 88.7% saving at −0.41%.")
    w()
    w("| workload | method | top-1 | top-5 | comp. save | energy save |")
    w("|---|---|---|---|---|---|")
    for r in f["rows"]:
        t5 = f"{r['acc5']*100:.2f}%" if "acc5" in r else "-"
        cs = f"{r['comp_saving']*100:.1f}%" if "comp_saving" in r else "-"
        es = f"{r['energy_saving']*100:.1f}%" if "energy_saving" in r else "-"
        w(f"| {r['workload']} | {r['method']} | {r['acc']*100:.2f}% | {t5} | {cs} | {es} |")
    w()

f = load("finetune")
if f:
    w("### Sec. 4.5 — adapting a pre-trained model")
    w()
    w("Paper: head-only FT +0.30% vs E²-Train FT +1.37%, E²-Train 61.6% cheaper.")
    w()
    w(f"- pre-trained acc: {f['pretrain_acc']*100:.2f}%")
    w(f"- head-only FT: {f['headft_delta']*100:+.2f}% @ {f['headft_joules']:.3f} J")
    w(f"- E²-Train FT: {f['e2t_delta']*100:+.2f}% @ {f['e2t_joules']:.3f} J")
    w(f"- E²-Train energy saving vs head-only: {f['saving_vs_headft']*100:.1f}%")
    w()

text = "\n".join(out)
md = pathlib.Path("EXPERIMENTS.md").read_text()
md = md.replace("<!-- RESULTS -->", text)
pathlib.Path("EXPERIMENTS.md").write_text(md)
print(f"filled EXPERIMENTS.md with {len(out)} lines")
