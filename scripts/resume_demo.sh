#!/usr/bin/env bash
# Demonstrates the checkpoint subsystem end-to-end on the reference
# backend:
#   1. materialize the reference artifact families,
#   2. train with durable checkpoints (ckpt/v1 registry),
#   3. "power-cycle": resume from the newest checkpoint — the resumed
#      metrics are bitwise identical to an uninterrupted run,
#   4. serve straight from the registry with no in-process trainer
#      (cross-process weight publishing).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${BIN:-cargo run --release --quiet --bin e2train --}
CKPT_DIR=${CKPT_DIR:-checkpoints/demo}

$BIN gen-ref

echo "== train with checkpoints every 40 iters =="
# sgd32: the serve bench below resolves the family's sgd32 artifact, so
# the registry's state layout must match the served method.
$BIN train --family refmlp-tiny --method sgd32 --iters 120 \
  --ckpt-every 40 --ckpt-dir "$CKPT_DIR" --out RUN_full.json

echo "== resume from the newest checkpoint (registry: $CKPT_DIR) =="
$BIN resume "$CKPT_DIR" --out RUN_resumed.json

echo "== serve from the registry (no in-process trainer) =="
$BIN serve --family refmlp-tiny --registry "$CKPT_DIR" \
  --clients 2,8 --requests 16 --out BENCH_serve_registry.json

echo "registry contents:"
cat "$CKPT_DIR/MANIFEST.json"
