#!/usr/bin/env bash
# Launch the data-parallel sharded-training scaling bench and record
# BENCH_shard.json (schema bench_shard/v1) at the repo root.
#
# Usage: scripts/shard_bench.sh [extra e2train shard-bench flags...]
# e.g.:  scripts/shard_bench.sh --shards 1,2,4,8 --steps 120
#
# Release profile — step-latency scaling is meaningless in debug.
set -euo pipefail
cd "$(dirname "$0")/.."

exec cargo run --release --bin e2train -- shard-bench \
  --shards 1,2,4 \
  --steps 80 \
  --warmup 5 \
  --out BENCH_shard.json \
  "$@"
