"""Unit tests for the L2 building blocks (layers.py) and gate helpers."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# conv + flops
# --------------------------------------------------------------------------

def test_conv2d_same_shape_and_stride():
    rng = np.random.default_rng(0)
    x = _arr(rng, (2, 8, 8, 3))
    w = _arr(rng, (3, 3, 3, 5))
    assert L.conv2d(x, w, 1).shape == (2, 8, 8, 5)
    assert L.conv2d(x, w, 2).shape == (2, 4, 4, 5)


def test_conv2d_matches_manual_1x1():
    """1x1 conv is a per-pixel matmul — verify against einsum."""
    rng = np.random.default_rng(1)
    x = _arr(rng, (2, 4, 4, 3))
    w = _arr(rng, (1, 1, 3, 6))
    out = L.conv2d(x, w, 1)
    ref = jnp.einsum("nhwc,co->nhwo", x, w[0, 0])
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    h=st.integers(1, 33),
    k=st.sampled_from([1, 3, 5]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
)
def test_conv_flops_formula(h, k, cin, cout, stride):
    f = L.conv_flops(h, h, k, k, cin, cout, stride)
    oh = -(-h // stride)
    assert f == oh * oh * k * k * cin * cout
    assert f > 0


# --------------------------------------------------------------------------
# batchnorm
# --------------------------------------------------------------------------

def test_bn_train_normalizes():
    rng = np.random.default_rng(2)
    x = _arr(rng, (16, 4, 4, 8), scale=5.0) + 3.0
    out, mean, var = L.bn_train(x, jnp.ones(8), jnp.zeros(8))
    np.testing.assert_allclose(jnp.mean(out, axis=(0, 1, 2)), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.var(out, axis=(0, 1, 2)), 1.0, atol=1e-2)
    np.testing.assert_allclose(mean, jnp.mean(x, axis=(0, 1, 2)), rtol=1e-5)


def test_bn_eval_uses_running_stats():
    rng = np.random.default_rng(3)
    x = _arr(rng, (4, 2, 2, 3))
    rmean = jnp.asarray([1.0, 2.0, 3.0])
    rvar = jnp.asarray([4.0, 4.0, 4.0])
    out = L.bn_eval(x, jnp.ones(3), jnp.zeros(3), rmean, rvar)
    ref = (x - rmean) / jnp.sqrt(rvar + L.BN_EPS)
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_bn_scale_bias_affine():
    rng = np.random.default_rng(4)
    x = _arr(rng, (8, 2, 2, 2))
    scale = jnp.asarray([2.0, 0.5])
    bias = jnp.asarray([1.0, -1.0])
    out, _, _ = L.bn_train(x, scale, bias)
    base, _, _ = L.bn_train(x, jnp.ones(2), jnp.zeros(2))
    np.testing.assert_allclose(out, base * scale + bias, rtol=1e-5, atol=1e-5)


def test_ema_moves_toward_batch():
    r = jnp.zeros(3)
    b = jnp.ones(3)
    out = L.ema(r, b)
    np.testing.assert_allclose(out, jnp.full(3, L.BN_MOMENTUM), rtol=1e-6)


# --------------------------------------------------------------------------
# loss + metrics
# --------------------------------------------------------------------------

def test_softmax_xent_uniform_logits():
    logits = jnp.zeros((4, 10))
    y = jnp.asarray([0, 1, 2, 3], jnp.int32)
    loss, _ = L.softmax_xent(logits, y)
    np.testing.assert_allclose(loss, np.log(10.0), rtol=1e-5)


def test_softmax_xent_correct_count():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]])
    y = jnp.asarray([0, 1, 1], jnp.int32)
    _, correct = L.softmax_xent(logits, y)
    assert float(correct) == 2.0


def test_softmax_xent_grad_is_prob_minus_onehot():
    rng = np.random.default_rng(5)
    logits = _arr(rng, (3, 5))
    y = jnp.asarray([1, 0, 4], jnp.int32)
    g = jax.grad(lambda l: L.softmax_xent(l, y)[0])(logits)
    p = jax.nn.softmax(logits)
    onehot = jax.nn.one_hot(y, 5)
    np.testing.assert_allclose(g, (p - onehot) / 3.0, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# LSTM cell
# --------------------------------------------------------------------------

def test_lstm_cell_shapes_and_bounds():
    rng = np.random.default_rng(6)
    specs = L.lstm_specs("g")
    from compile.layers import materialize

    p = materialize(specs, seed=0)
    x = _arr(rng, (4, L.GATE_DIM))
    h = jnp.zeros((4, L.GATE_DIM))
    c = jnp.zeros((4, L.GATE_DIM))
    h2, c2 = L.lstm_cell(x, h, c, p["g.wi"], p["g.wh"], p["g.b"])
    assert h2.shape == (4, L.GATE_DIM)
    assert float(jnp.max(jnp.abs(h2))) <= 1.0  # tanh-bounded


def test_lstm_state_carries_information():
    rng = np.random.default_rng(7)
    from compile.layers import materialize

    p = materialize(L.lstm_specs("g"), seed=1)
    x1 = _arr(rng, (2, L.GATE_DIM))
    x2 = _arr(rng, (2, L.GATE_DIM))
    h0 = jnp.zeros((2, L.GATE_DIM))
    c0 = jnp.zeros((2, L.GATE_DIM))
    h1, c1 = L.lstm_cell(x1, h0, c0, p["g.wi"], p["g.wh"], p["g.b"])
    out_seq, _ = L.lstm_cell(x2, h1, c1, p["g.wi"], p["g.wh"], p["g.b"])
    out_fresh, _ = L.lstm_cell(x2, h0, c0, p["g.wi"], p["g.wh"], p["g.b"])
    assert not np.allclose(out_seq, out_fresh)  # history matters


# --------------------------------------------------------------------------
# materialize
# --------------------------------------------------------------------------

def test_materialize_he_statistics():
    from compile.layers import materialize

    p = materialize({"w": ((3, 3, 16, 64), "he")}, seed=0)["w"]
    std = float(jnp.std(p))
    expect = np.sqrt(2.0 / (3 * 3 * 16))
    assert abs(std - expect) / expect < 0.1


def test_materialize_kinds():
    from compile.layers import materialize

    p = materialize(
        {"a": ((4,), "zeros"), "b": ((4,), "ones"), "c": ((8, 2), "uniform")},
        seed=0,
    )
    assert float(jnp.sum(jnp.abs(p["a"]))) == 0.0
    assert float(jnp.sum(p["b"])) == 4.0
    assert float(jnp.max(jnp.abs(p["c"]))) <= 1.0 / np.sqrt(8)
