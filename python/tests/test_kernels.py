"""L1 correctness: every Pallas kernel vs. its pure-jnp oracle.

Hypothesis sweeps shapes/bit-widths; assert_allclose against ref.py is the
core correctness signal for the kernels that end up inlined in the AOT
artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# quantize
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 40),
    bits=st.sampled_from([2, 3, 4, 8, 10, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(rows, cols, bits, seed):
    rng = np.random.default_rng(seed)
    v = _arr(rng, (rows, cols), scale=3.0)
    np.testing.assert_allclose(
        K.quantize(v, bits), ref.quantize_ref(v, bits), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_zero_tensor(bits):
    v = jnp.zeros((7, 5), jnp.float32)
    np.testing.assert_array_equal(K.quantize(v, bits), v)


@pytest.mark.parametrize("bits", [2, 4, 8, 16])
def test_quantize_level_count(bits):
    """Quantized values live on at most 2^bits - 1 distinct levels."""
    rng = np.random.default_rng(0)
    v = _arr(rng, (64, 64))
    q = np.asarray(K.quantize(v, bits))
    assert len(np.unique(q)) <= 2**bits - 1


def test_quantize_preserves_extremes():
    """max-abs element is exactly representable (scale anchor)."""
    rng = np.random.default_rng(1)
    v = _arr(rng, (33, 9))
    q = np.asarray(K.quantize(v, 8))
    i = np.unravel_index(np.argmax(np.abs(np.asarray(v))), v.shape)
    np.testing.assert_allclose(q[i], np.asarray(v)[i], rtol=1e-6)


@pytest.mark.parametrize("shape", [(5,), (3, 4, 5), (2, 3, 4, 5)])
def test_quantize_any_rank(shape):
    rng = np.random.default_rng(2)
    v = _arr(rng, shape)
    np.testing.assert_allclose(
        K.quantize(v, 6), ref.quantize_ref(v, 6), rtol=1e-6, atol=1e-6
    )


def test_quantize_error_shrinks_with_bits():
    rng = np.random.default_rng(3)
    v = _arr(rng, (128, 32))
    errs = [float(jnp.max(jnp.abs(K.quantize(v, b) - v))) for b in (2, 4, 8, 12)]
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < errs[0] / 50


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 90),
    k=st.integers(1, 90),
    n=st.integers(1, 90),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, (m, k)), _arr(rng, (k, n))
    np.testing.assert_allclose(
        K.matmul(a, b), ref.matmul_ref(a, b), rtol=2e-5, atol=2e-5
    )


def test_matmul_multi_tile():
    """Shapes crossing several (128,128,128) tiles exercise accumulation."""
    rng = np.random.default_rng(7)
    a, b = _arr(rng, (300, 260)), _arr(rng, (260, 150))
    np.testing.assert_allclose(
        K.matmul(a, b), ref.matmul_ref(a, b), rtol=3e-5, atol=3e-4
    )


def test_matmul_custom_tiles():
    rng = np.random.default_rng(8)
    a, b = _arr(rng, (65, 70)), _arr(rng, (70, 33))
    out = K.matmul(a, b, bm=32, bn=16, bk=8)
    np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=2e-5, atol=2e-5)


def test_vmem_budget():
    """Default tiling fits comfortably in a 16MiB VMEM (DESIGN.md §Perf)."""
    assert K.vmem_bytes() <= 16 * 1024 * 1024 // 4


# --------------------------------------------------------------------------
# psg_select / psg_matmul — Eq. (2)
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 60),
    cols=st.integers(1, 60),
    beta=st.sampled_from([0.01, 0.05, 0.1, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_psg_select_matches_ref(rows, cols, beta, seed):
    rng = np.random.default_rng(seed)
    gf, gm = _arr(rng, (rows, cols)), _arr(rng, (rows, cols))
    sel, mask = K.psg_select(gf, gm, beta)
    sel_r, mask_r = ref.psg_select_ref(gf, gm, beta)
    np.testing.assert_array_equal(sel, sel_r)
    np.testing.assert_array_equal(mask, mask_r)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 50),
    k=st.integers(2, 50),
    n=st.integers(2, 50),
    bits_x=st.sampled_from([3, 4, 6]),
    bits_gy=st.sampled_from([8, 10, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_psg_matmul_matches_ref(m, k, n, bits_x, bits_gy, seed):
    rng = np.random.default_rng(seed)
    x, gy = _arr(rng, (m, k)), _arr(rng, (m, n), scale=0.1)
    sel, mask = K.psg_matmul(x, gy, 0.05, bits_x, bits_gy)
    sel_r, mask_r = ref.psg_matmul_ref(x, gy, 0.05, bits_x, bits_gy)
    # The full-precision products may differ at float ulp level between the
    # tiled kernel and jnp matmul; only entries *below* threshold consult
    # the full product's sign, and only near-zero entries could flip.
    assert float(jnp.mean(sel == sel_r)) > 0.99
    np.testing.assert_array_equal(mask, mask_r)


def test_psg_select_all_confident_when_beta_zero():
    rng = np.random.default_rng(9)
    gf, gm = _arr(rng, (16, 16)), _arr(rng, (16, 16))
    _, mask = K.psg_select(gf, gm, 0.0)
    assert float(jnp.mean(mask)) == 1.0


def test_psg_select_fallback_dominates_at_beta_one():
    """beta=1: only the max-|g_msb| entry is confident."""
    rng = np.random.default_rng(10)
    gf, gm = _arr(rng, (32, 32)), _arr(rng, (32, 32))
    _, mask = K.psg_select(gf, gm, 1.0)
    assert 0 < float(jnp.sum(mask)) <= 32 * 32 * 0.05


def test_psg_predicted_fraction_realistic():
    """Paper (Sec. 4.4): predictor used >= 60% of entries at beta=0.05."""
    rng = np.random.default_rng(11)
    x, gy = _arr(rng, (256, 64)), _arr(rng, (256, 32), scale=0.01)
    _, mask = K.psg_matmul(x, gy, 0.05)
    assert float(jnp.mean(mask)) >= 0.6


def test_psg_signs_mostly_correct():
    """Predicted signs agree with the true full-precision signs for the
    overwhelming majority of confidently-predicted entries (Eq. 3)."""
    rng = np.random.default_rng(12)
    x, gy = _arr(rng, (512, 48)), _arr(rng, (512, 24))
    sel, mask = K.psg_matmul(x, gy, 0.05)
    true_sign = jnp.sign(x.T @ gy)
    agree = jnp.where(mask > 0, (sel == true_sign).astype(jnp.float32), 1.0)
    assert float(jnp.mean(agree)) > 0.95


def test_psg_error_bound_direction():
    """Eq. (3): the bound shrinks exponentially as predictor bits grow."""
    rng = np.random.default_rng(13)
    x, gy = _arr(rng, (128, 32)), _arr(rng, (128, 16))
    b_lo = K.prediction_error_bound(x, gy, 0.05, bits_x=2, bits_gy=6)
    b_mid = K.prediction_error_bound(x, gy, 0.05, bits_x=4, bits_gy=10)
    b_hi = K.prediction_error_bound(x, gy, 0.05, bits_x=8, bits_gy=14)
    assert b_lo > b_mid > b_hi


# --------------------------------------------------------------------------
# gated_residual
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 16),
    c=st.integers(1, 24),
    hw=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_gated_residual_matches_ref(n, c, hw, seed):
    rng = np.random.default_rng(seed)
    x, fx = _arr(rng, (n, hw, hw, c)), _arr(rng, (n, hw, hw, c))
    g = jnp.asarray(rng.uniform(size=(n,)).astype(np.float32))
    np.testing.assert_allclose(
        K.gated_residual(x, fx, g),
        ref.gated_residual_ref(x, fx, g),
        rtol=1e-6,
        atol=1e-6,
    )


def test_gated_residual_zero_gate_is_identity():
    rng = np.random.default_rng(14)
    x, fx = _arr(rng, (4, 6, 6, 8)), _arr(rng, (4, 6, 6, 8))
    out = K.gated_residual(x, fx, jnp.zeros((4,), jnp.float32))
    np.testing.assert_array_equal(out, x)


def test_gated_residual_grads():
    """Custom VJP: zero gate kills the branch gradient (SLU backward skip)."""
    import jax

    rng = np.random.default_rng(15)
    x, fx = _arr(rng, (3, 4, 4, 2)), _arr(rng, (3, 4, 4, 2))
    g = jnp.asarray([0.0, 1.0, 0.5], jnp.float32)

    def f(fx_):
        return jnp.sum(K.gated_residual(x, fx_, g) ** 2)

    dfx = jax.grad(f)(fx)
    assert float(jnp.max(jnp.abs(dfx[0]))) == 0.0  # gate 0: no branch grad
    assert float(jnp.max(jnp.abs(dfx[1]))) > 0.0
