"""L2 correctness: architectures, gates, and the manual-backprop train
steps, for every method variant.

The block-level VJP backward is validated against jax.grad on the same
loss (they must agree exactly for the plain-SGD method, where no
quantization or sign tricks intervene).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import archs, gates, layers as L, model


BATCH = 4


def tiny_arch(qbits=None, classes=10):
    return archs.resnet(1, classes, image_size=8, width=0.25, qbits=qbits)


def make_inputs(ins, seed=0, lr=0.1):
    rng = np.random.default_rng(seed)
    flat = []
    for spec in ins:
        if spec.role in ("param", "mom", "state"):
            flat.append(
                L.materialize({spec.name: (tuple(spec.shape), spec.init)}, seed=1)[
                    spec.name
                ]
            )
        elif spec.name == "x":
            flat.append(
                jnp.asarray(rng.normal(size=spec.shape).astype(np.float32))
            )
        elif spec.name == "y":
            nc = 10
            flat.append(
                jnp.asarray(rng.integers(0, nc, size=spec.shape).astype(np.int32))
            )
        elif spec.name == "lr":
            flat.append(jnp.float32(lr))
        elif spec.name == "alpha":
            flat.append(jnp.float32(1.0))
        elif spec.name == "beta":
            flat.append(jnp.float32(0.05))
        elif spec.role == "mask":
            flat.append(jnp.ones(spec.shape, jnp.float32))
        else:
            raise AssertionError(spec)
    return flat


def out_by_name(outs, result, name):
    idx = [i for i, o in enumerate(outs) if o.name == name]
    return result[idx[0]] if idx else None


# --------------------------------------------------------------------------
# Architectures
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,depth", [(1, 8), (3, 20), (6, 38)])
def test_resnet_family_structure(n, depth):
    a = archs.resnet(n, 10, image_size=16, width=0.5)
    assert a.name == f"resnet{depth}"
    # stem + 3n blocks
    assert len(a.blocks) == 1 + 3 * n
    # downsample blocks (first of stages 1, 2) are not gateable
    gated = a.gated_blocks()
    assert len(gated) == 3 * n - 2
    assert a.total_flops() > 0
    fracs = a.gated_flop_fracs()
    assert len(fracs) == len(gated)
    assert all(0 < f < 1 for f in fracs)


def test_mobilenet_structure():
    a = archs.mobilenet_v2(10, image_size=16, width=0.35,
                           cfg=[(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 2, 1)])
    assert a.name == "mobilenetv2"
    # only identity-skip blocks are gateable
    for b in a.blocks[1:]:
        skip = b.in_ch == b.out_ch
        assert b.gateable == (skip and b.gateable) or not b.gateable


def test_param_specs_deterministic_order():
    a1 = tiny_arch()
    a2 = tiny_arch()
    assert list(a1.param_specs().keys()) == list(a2.param_specs().keys())


def test_bn_state_matches_bn_params():
    a = tiny_arch()
    pspecs, sspecs = a.param_specs(), a.bn_state_specs()
    scales = [k for k in pspecs if k.endswith(".scale")]
    rmeans = [k for k in sspecs if k.endswith(".rmean")]
    assert len(scales) == len(rmeans)


# --------------------------------------------------------------------------
# Forward/eval consistency
# --------------------------------------------------------------------------

def test_eval_step_shapes_and_determinism():
    a = tiny_arch()
    step, ins, outs = model.build_eval_step(a, model.METHODS["sgd32"], BATCH)
    flat = make_inputs(ins)
    r1 = jax.jit(step)(*flat)
    r2 = jax.jit(step)(*flat)
    assert len(r1) == len(outs)
    np.testing.assert_array_equal(r1[0], r2[0])
    correct = float(out_by_name(outs, r1, "correct"))
    correct5 = float(out_by_name(outs, r1, "correct5"))
    assert 0 <= correct <= BATCH
    assert correct <= correct5 <= BATCH


# --------------------------------------------------------------------------
# Train steps: every method
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mname", list(model.METHODS.keys()))
def test_train_step_runs_and_updates(mname):
    m = model.METHODS[mname]
    a = tiny_arch(qbits=m.qbits_act)
    step, ins, outs = model.build_train_step(a, m, BATCH)
    flat = make_inputs(ins)
    result = jax.jit(step)(*flat)
    assert len(result) == len(outs)
    loss = float(out_by_name(outs, result, "loss"))
    assert np.isfinite(loss) and loss > 0
    # head weight must move (it always trains, in every method)
    pnames = [s.name for s in ins if s.role == "param"]
    hw_i = pnames.index("head.w")
    before = np.asarray(flat[hw_i])
    after = np.asarray(result[hw_i])
    assert not np.allclose(before, after)
    if mname == "headft":
        # trunk frozen: first conv unchanged
        c_i = pnames.index("stem.conv")
        np.testing.assert_array_equal(flat[c_i], result[c_i])


def test_manual_backprop_matches_jax_grad():
    """The block-VJP backward equals whole-graph jax.grad for plain SGD."""
    m = model.METHODS["sgd32"]
    a = tiny_arch()
    step, ins, outs = model.build_train_step(a, m, BATCH)
    flat = make_inputs(ins, lr=1.0)
    pnames = [s.name for s in ins if s.role == "param"]
    nP = len(pnames)
    params = {n: v for n, v in zip(pnames, flat[:nP])}
    x, y = flat[2 * nP + len([s for s in ins if s.role == "state"])], None
    # locate x/y by spec
    xi = [i for i, s in enumerate(ins) if s.name == "x"][0]
    yi = [i for i, s in enumerate(ins) if s.name == "y"][0]
    x, y = flat[xi], flat[yi]

    def loss_fn(p):
        a_ = x
        ones = jnp.ones((BATCH,), jnp.float32)
        for blk in a.blocks:
            bp = {k: p[k] for k in blk.specs}
            a_, _ = blk.apply_train(bp, a_, ones)
        logits = a.head_apply(p, a_)
        l, _ = L.softmax_xent(logits, y)
        return l

    ref_grads = jax.grad(loss_fn)(params)
    result = jax.jit(step)(*flat)
    # new_w = w - lr*(mu*0 + g + wd*w); with lr=1, mom=0 initial:
    # g_step = w_before - w_after - wd*w_before
    wd = m.weight_decay
    for i, name in enumerate(pnames):
        g_step = np.asarray(flat[i]) - np.asarray(result[i]) - wd * np.asarray(flat[i])
        np.testing.assert_allclose(
            g_step, np.asarray(ref_grads[name]), rtol=2e-3, atol=2e-5,
            err_msg=name,
        )


def test_sd_mask_zero_freezes_gated_blocks():
    m = model.METHODS["sd"]
    a = tiny_arch()
    step, ins, outs = model.build_train_step(a, m, BATCH)
    flat = make_inputs(ins)
    mi = [i for i, s in enumerate(ins) if s.role == "mask"][0]
    flat[mi] = jnp.zeros_like(flat[mi])
    result = jax.jit(step)(*flat)
    pnames = [s.name for s in ins if s.role == "param"]
    gated = a.gated_blocks()
    for blk in gated:
        for pname in blk.specs:
            if model.is_weight(pname):
                i = pnames.index(pname)
                # only weight-decay drift allowed: |Δ| <= lr*wd*|w| (+eps)
                dw = np.abs(np.asarray(flat[i]) - np.asarray(result[i]))
                bound = 0.1 * m.weight_decay * np.abs(np.asarray(flat[i])) + 1e-7
                assert (dw <= bound + 1e-6).all(), pname


def test_psg_updates_are_sign_scaled():
    """PSG weight deltas are exactly ±lr or 0 (sign updates)."""
    m = model.METHODS["psg"]
    a = tiny_arch(qbits=m.qbits_act)
    step, ins, outs = model.build_train_step(a, m, BATCH)
    lr = 0.01
    flat = make_inputs(ins, lr=lr)
    result = jax.jit(step)(*flat)
    pnames = [s.name for s in ins if s.role == "param"]
    i = pnames.index("s0b0.conv1")
    delta = np.asarray(flat[i]) - np.asarray(result[i])
    vals = np.unique(np.round(np.abs(delta) / lr, 3))
    assert set(vals.tolist()) <= {0.0, 1.0}, vals


def test_psg_frac_in_range():
    m = model.METHODS["e2train"]
    a = tiny_arch(qbits=m.qbits_act)
    step, ins, outs = model.build_train_step(a, m, BATCH)
    result = jax.jit(step)(*make_inputs(ins))
    frac = float(out_by_name(outs, result, "psg_frac"))
    assert 0.0 <= frac <= 1.0
    # Paper observes >=60% predictor usage at beta=0.05.
    assert frac >= 0.4


def test_gate_fracs_shape_and_range():
    m = model.METHODS["slu"]
    a = tiny_arch()
    step, ins, outs = model.build_train_step(a, m, BATCH)
    result = jax.jit(step)(*make_inputs(ins))
    fr = np.asarray(out_by_name(outs, result, "gate_fracs"))
    assert fr.shape == (len(a.gated_blocks()),)
    assert ((fr >= 0) & (fr <= 1)).all()


def test_bn_running_stats_move():
    m = model.METHODS["sgd32"]
    a = tiny_arch()
    step, ins, outs = model.build_train_step(a, m, BATCH)
    flat = make_inputs(ins)
    snames = [s.name for s in ins if s.role == "state"]
    offset = len([s for s in ins if s.role in ("param", "mom")])
    result = jax.jit(step)(*flat)
    moved = 0
    for j, sname in enumerate(snames):
        if not np.allclose(flat[offset + j], result[offset + j]):
            moved += 1
    assert moved == len(snames)  # every BN stat EMA-updates


def test_loss_decreases_over_steps():
    """A few steps on a fixed batch must reduce the loss (sanity)."""
    m = model.METHODS["sgd32"]
    a = tiny_arch()
    step_fn, ins, outs = model.build_train_step(a, m, BATCH)
    step = jax.jit(step_fn)
    flat = make_inputs(ins, lr=0.05)
    n_state = len([s for s in ins if s.role in ("param", "mom", "state")])
    first = None
    for it in range(8):
        result = step(*flat)
        loss = float(out_by_name(outs, result, "loss"))
        if first is None:
            first = loss
        flat[:n_state] = list(result[:n_state])
    assert loss < first, (first, loss)


# --------------------------------------------------------------------------
# Gates
# --------------------------------------------------------------------------

def test_gate_trajectory_shapes():
    gp = L.materialize(gates.gate_specs([4, 8]), seed=0)
    pooled = [jnp.ones((BATCH, 4)), jnp.ones((BATCH, 8)), jnp.ones((BATCH, 4))]
    probs = gates.trajectory(gp, pooled)
    assert len(probs) == 3
    for p in probs:
        assert p.shape == (BATCH,)
        assert ((p >= 0) & (p <= 1)).all()


def test_straight_through_gradient_is_identity():
    p = jnp.asarray([0.3, 0.7])
    g = jax.grad(lambda v: jnp.sum(gates.straight_through(v) * 2.0))(p)
    np.testing.assert_allclose(g, [2.0, 2.0])


def test_gate_flops_tiny_vs_trunk():
    a = archs.resnet(3, 10, image_size=32, width=1.0)
    gf = gates.gate_flops([b.in_ch for b in a.gated_blocks()])
    # Appendix C: gates cost ~0.04% of the trunk.
    assert gf / a.total_flops() < 0.005
