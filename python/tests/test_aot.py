"""AOT interface invariants: manifest structure, buffer ordering, and the
HLO-text lowering contract the rust runtime depends on."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile import aot, archs, configs, model

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def tiny_cfg():
    return configs.ArchCfg("t", "resnet", 1, 10, 8, 0.25, 4, 8)


# --------------------------------------------------------------------------
# Manifest / IoSpec ordering invariants (the rust contract)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mname", list(model.METHODS.keys()))
def test_io_ordering_params_then_mom_then_state(mname):
    m = model.METHODS[mname]
    arch = tiny_cfg().build(qbits=m.qbits_act)
    _, ins, outs = model.build_train_step(arch, m, 4)
    roles = [s.role for s in ins]
    order = {"param": 0, "mom": 1, "state": 2, "data": 3, "scalar": 4, "mask": 5}
    ranks = [order[r] for r in roles]
    assert ranks == sorted(ranks), f"{mname}: role order broken: {roles}"
    # outputs mirror the state prefix then metrics
    oroles = [s.role for s in outs]
    oorder = {"out_param": 0, "out_mom": 1, "out_state": 2, "out_metric": 3}
    oranks = [oorder[r] for r in oroles]
    assert oranks == sorted(oranks)
    # state prefix counts match exactly (the rust write-back contract)
    n_in = sum(1 for r in roles if r in ("param", "mom", "state"))
    n_out = sum(1 for r in oroles if r != "out_metric")
    assert n_in == n_out


@pytest.mark.parametrize("mname", ["sgd32", "slu", "e2train", "sd"])
def test_output_names_match_input_state_names(mname):
    m = model.METHODS[mname]
    arch = tiny_cfg().build(qbits=m.qbits_act)
    _, ins, outs = model.build_train_step(arch, m, 4)
    in_state = [s.name for s in ins if s.role in ("param", "mom", "state")]
    out_state = [s.name for s in outs if s.role != "out_metric"]
    assert in_state == out_state


def test_manifest_build_contains_cost_tables():
    cfg = tiny_cfg()
    m = model.METHODS["e2train"]
    arch = cfg.build(qbits=m.qbits_act)
    step, tins, touts = model.build_train_step(arch, m, cfg.batch)
    estep, eins, eouts = model.build_eval_step(arch, m, cfg.eval_batch)
    man = aot.build_manifest(cfg, m, arch, tins, touts, eins, eouts)
    assert man["total_flops"] == arch.total_flops()
    assert len(man["blocks"]) == len(arch.blocks)
    assert len(man["gated_flop_fracs"]) == len(arch.gated_blocks())
    assert man["gate_flops"] > 0
    assert man["param_count"] > 0
    # JSON-serializable end to end
    json.loads(json.dumps(man))


# --------------------------------------------------------------------------
# HLO text lowering
# --------------------------------------------------------------------------

def test_hlo_text_lowering_tiny():
    """The lowering path produces parseable HLO text with ids the old
    xla_extension accepts (the whole reason we ship text, not protos)."""
    cfg = tiny_cfg()
    m = model.METHODS["sgd32"]
    arch = cfg.build()
    step, tins, _ = model.build_train_step(arch, m, cfg.batch)
    lowered = jax.jit(step).lower(*[aot._abstract(s) for s in tins])
    txt = aot.to_hlo_text(lowered)
    assert txt.startswith("HloModule")
    assert "ENTRY" in txt
    # tuple return (rust unwraps with to_tuple)
    assert "tuple(" in txt or "(f32[" in txt


def test_built_artifacts_match_manifests():
    """Every shipped manifest's input count equals what the model builder
    reproduces today (guards against silent drift between aot runs)."""
    if not (ARTIFACTS / "index.json").exists():
        pytest.skip("artifacts not built")
    fam = "resnet8-c10-tiny"
    for mname in ("sgd32", "e2train"):
        man = json.loads((ARTIFACTS / fam / f"{mname}.json").read_text())
        cfg = configs.ARCH_CFGS[fam]
        m = model.METHODS[mname]
        arch = cfg.build(qbits=m.qbits_act)
        _, ins, outs = model.build_train_step(arch, m, cfg.batch)
        assert len(man["train_inputs"]) == len(ins), mname
        assert len(man["train_outputs"]) == len(outs), mname
        assert man["total_flops"] == arch.total_flops()


def test_presets_reference_known_families():
    for preset, fams in configs.PRESETS.items():
        for f in fams:
            assert f in configs.ARCH_CFGS, (preset, f)


def test_arch_cfg_build_both_kinds():
    r = configs.ARCH_CFGS["resnet8-c10-tiny"].build()
    assert r.name == "resnet8"
    mb = configs.ARCH_CFGS["mbv2-c10-tiny"].build()
    assert mb.name == "mobilenetv2"
    with pytest.raises(ValueError):
        configs.ArchCfg("x", "vgg", 1, 10, 8, 1.0, 4, 8).build()
