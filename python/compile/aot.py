"""AOT driver: lower every (arch, method) train/eval step to HLO text +
a JSON manifest the rust coordinator consumes.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version under the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Layout:

    artifacts/
      index.json                      # everything that was built
      <family>/<method>.train.hlo.txt
      <family>/<method>.eval.hlo.txt
      <family>/<method>.json          # manifest: io specs + cost tables

The manifest is the *entire* contract with rust: buffer order, shapes,
dtypes, initializer kinds, per-block FLOPs/gateability (energy ledger),
and the static gate-FLOPs overhead.

Usage:  python -m compile.aot [--preset default|tiny|paper]
                              [--families a,b] [--methods m1,m2] [--out DIR]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs as C
from . import gates as G
from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract(spec: M.IoSpec):
    dt = jnp.int32 if spec.dtype == "i32" else jnp.float32
    return jax.ShapeDtypeStruct(tuple(spec.shape), dt)


def _spec_dicts(specs):
    return [
        {
            "name": s.name,
            "role": s.role,
            "shape": list(s.shape),
            "dtype": s.dtype,
            "init": s.init,
        }
        for s in specs
    ]


def build_manifest(cfg: C.ArchCfg, method: M.MethodSpec, arch, tins, touts, eins, eouts):
    gated = arch.gated_blocks()
    return {
        "family": cfg.name,
        "method": dataclasses.asdict(method),
        "arch": {
            "name": arch.name,
            "kind": cfg.arch,
            "num_classes": arch.num_classes,
            "image_size": arch.image_size,
            "batch": cfg.batch,
            "eval_batch": cfg.eval_batch,
            "width": cfg.width,
            "feat_ch": arch.feat_ch,
        },
        "train_inputs": _spec_dicts(tins),
        "train_outputs": _spec_dicts(touts),
        "eval_inputs": _spec_dicts(eins),
        "eval_outputs": _spec_dicts(eouts),
        "blocks": [
            {
                "name": b.name,
                "flops": b.flops,
                "gateable": b.gateable,
                "in_ch": b.in_ch,
                "out_ch": b.out_ch,
                "in_hw": b.in_hw,
                "params": sorted(b.specs.keys()),
            }
            for b in arch.blocks
        ],
        "head_flops": arch.head_flops,
        "total_flops": arch.total_flops(),
        "gated_flop_fracs": arch.gated_flop_fracs(),
        "gate_flops": G.gate_flops([b.in_ch for b in gated]) if gated else 0,
        "param_count": sum(
            int(jnp.prod(jnp.array(s.shape))) if s.shape else 1
            for s in tins
            if s.role == "param"
        ),
    }


def lower_one(cfg: C.ArchCfg, mname: str, outdir: Path, verbose: bool = True):
    method = M.METHODS[mname]
    arch = cfg.build(qbits=method.qbits_act)

    t0 = time.time()
    step, tins, touts = M.build_train_step(arch, method, cfg.batch)
    train_lowered = jax.jit(step).lower(*[_abstract(s) for s in tins])
    train_txt = to_hlo_text(train_lowered)

    estep, eins, eouts = M.build_eval_step(arch, method, cfg.eval_batch)
    eval_lowered = jax.jit(estep).lower(*[_abstract(s) for s in eins])
    eval_txt = to_hlo_text(eval_lowered)

    fam = outdir / cfg.name
    fam.mkdir(parents=True, exist_ok=True)
    (fam / f"{mname}.train.hlo.txt").write_text(train_txt)
    (fam / f"{mname}.eval.hlo.txt").write_text(eval_txt)
    manifest = build_manifest(cfg, method, arch, tins, touts, eins, eouts)
    (fam / f"{mname}.json").write_text(json.dumps(manifest, indent=1))
    if verbose:
        print(
            f"  {cfg.name}/{mname}: train={len(train_txt)//1024}KiB "
            f"eval={len(eval_txt)//1024}KiB "
            f"params={manifest['param_count']} ({time.time()-t0:.1f}s)",
            flush=True,
        )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="default", choices=sorted(C.PRESETS))
    ap.add_argument("--families", default="", help="comma list; overrides preset")
    ap.add_argument("--methods", default=",".join(C.DEFAULT_METHODS))
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[2] / "artifacts"))
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    families = (
        [f for f in args.families.split(",") if f]
        or C.PRESETS[args.preset]
    )
    methods = [m for m in args.methods.split(",") if m]

    index = {"families": {}, "methods": methods}
    t0 = time.time()
    for fname in families:
        cfg = C.ARCH_CFGS[fname]
        print(f"[aot] {fname} (batch={cfg.batch})", flush=True)
        index["families"][fname] = {
            "methods": methods,
            "batch": cfg.batch,
            "eval_batch": cfg.eval_batch,
        }
        for mname in methods:
            lower_one(cfg, mname, outdir)
    (outdir / "index.json").write_text(json.dumps(index, indent=1))
    print(f"[aot] done: {len(families)} families x {len(methods)} methods "
          f"in {time.time()-t0:.1f}s -> {outdir}")


if __name__ == "__main__":
    sys.exit(main())
