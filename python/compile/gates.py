"""RNNGates for input-dependent selective layer update (SLU, Sec. 3.2 +
appendix C).

Per gated block: global-average-pool the block input, project to a
10-dim vector (one projection per distinct channel width, since pooled
dims differ across stages), run one step of a *shared* single-layer
LSTM(10) whose hidden state is carried across blocks, and map the hidden
state to a scalar probability.  Hard decisions use a straight-through
estimator so the gates are learned jointly with the trunk from scratch —
no RL post-processing, which is the paper's point vs. SkipNet [19].

The FLOPs regularizer C(W, G) of Eq. (1) is applied by the train-step
builder using the static per-block FLOP fractions from the Arch.

Gate gradients: the trunk backward produces dL/d(mask_b) for each gated
block; the trajectory below is re-run under jax.vjp with those cotangents
(plus the regularizer term) to get gate-parameter gradients.  Pooled block
inputs are treated as constants (stop-gradient) on the gate path — the
gate's learning signal flows through its *decision*, not back into the
trunk activations, matching the negligible-overhead claim (<0.04% FLOPs).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import layers as L

Params = Dict[str, jnp.ndarray]


def gate_specs(channel_dims: Sequence[int]) -> Dict[str, L.Spec]:
    """Parameter specs: per-width projection + shared LSTM + output head."""
    specs: Dict[str, L.Spec] = {}
    for c in sorted(set(channel_dims)):
        specs[f"gate.proj{c}.w"] = ((c, L.GATE_DIM), "uniform")
        specs[f"gate.proj{c}.b"] = ((L.GATE_DIM,), "zeros")
    specs.update(L.lstm_specs("gate.lstm"))
    specs["gate.out.w"] = ((L.GATE_DIM, 1), "uniform")
    # Positive bias: gates start OPEN (prob > 0.5), so early training uses
    # the full model and the FLOPs regularizer prunes from there.  A zero
    # bias starts every block skipped (prob == 0.5 fails the hard > 0.5
    # test) and the gates never receive a usefulness signal.
    specs["gate.out.b"] = ((1,), "ones")
    return specs


def gate_step(
    gp: Params,
    pooled: jnp.ndarray,
    h: jnp.ndarray,
    c: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One gate decision. pooled: (N, C). Returns (prob (N,), h', c')."""
    cdim = pooled.shape[-1]
    z = pooled @ gp[f"gate.proj{cdim}.w"] + gp[f"gate.proj{cdim}.b"]
    h, c = L.lstm_cell(z, h, c, gp["gate.lstm.wi"], gp["gate.lstm.wh"], gp["gate.lstm.b"])
    logit = (h @ gp["gate.out.w"] + gp["gate.out.b"])[:, 0]
    return jax.nn.sigmoid(logit), h, c


def straight_through(prob: jnp.ndarray) -> jnp.ndarray:
    """Hard {0,1} decision in the forward pass, identity gradient."""
    hard = (prob > 0.5).astype(prob.dtype)
    return hard + prob - jax.lax.stop_gradient(prob)


def trajectory(
    gp: Params, pooled_list: List[jnp.ndarray]
) -> List[jnp.ndarray]:
    """Gate probabilities for each gated block, LSTM state carried.

    ``pooled_list`` entries are already stop-gradded by the caller; this
    function is pure in ``gp`` so it can be re-run under jax.vjp in the
    gate-backward phase with the trunk's dL/d(mask) cotangents.
    """
    if not pooled_list:
        return []
    n = pooled_list[0].shape[0]
    h = jnp.zeros((n, L.GATE_DIM), jnp.float32)
    c = jnp.zeros((n, L.GATE_DIM), jnp.float32)
    probs = []
    for pooled in pooled_list:
        p, h, c = gate_step(gp, pooled, h, c)
        probs.append(p)
    return probs


def gate_flops(channel_dims: Sequence[int]) -> int:
    """MACs of the gate path per sample (projection + LSTM + head) —
    exported to the manifest so the energy ledger can charge the (tiny)
    gate overhead, substantiating the paper's <0.04% claim."""
    total = 0
    for c in channel_dims:
        total += c * L.GATE_DIM  # projection
        total += 2 * L.GATE_DIM * 4 * L.GATE_DIM  # lstm matmuls
        total += L.GATE_DIM  # head
    return total
