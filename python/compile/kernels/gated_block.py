"""Pallas gated residual merge kernel (Layer 1) — SLU's skip primitive.

``out[n] = x[n] + gate[n] * f(x)[n]`` with a per-sample gate in [0, 1].

This is the datapath half of input-dependent selective layer update
(Sec. 3.2): a gate of 0 turns the block into an identity for that sample
in the forward pass, and — because the gate multiplies the branch output —
zeroes the branch's weight gradient for that sample in the backward pass.
The *scheduling* half (not launching skipped blocks at all) lives in the
rust coordinator's block-chained mode; this kernel covers the per-sample
masked execution inside one fused train-step artifact.

Grid: (N, F/block) over samples x flattened features; the gate value for
the sample is a resident (1,1) block per grid row.

Correctness oracle: ref.gated_residual_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

_BLOCK_F = 512


def _gated_kernel(gate_ref, x_ref, fx_ref, o_ref):
    g = gate_ref[0, 0]
    o_ref[...] = x_ref[...] + g * fx_ref[...]


@jax.custom_vjp
def gated_residual(
    x: jnp.ndarray, fx: jnp.ndarray, gate: jnp.ndarray
) -> jnp.ndarray:
    """Per-sample gated residual over (N, ...) tensors; gate is (N,).

    Differentiation: analytic custom VJP (Pallas calls carry no autodiff
    rule) — d/dx = g, d/dfx = gate * g, d/dgate[n] = <g[n], fx[n]>.  The
    gate factor in d/dfx is exactly the paper's "skipped blocks receive
    no weight update" (Sec. 3.2): a zero gate kills the branch cotangent
    for that sample before it reaches the branch weights.
    """
    assert x.shape == fx.shape and gate.shape == (x.shape[0],)
    n = x.shape[0]
    feat = 1
    for d in x.shape[1:]:
        feat *= d
    xf = x.reshape(n, feat)
    ff = fx.reshape(n, feat)
    pad = (-feat) % _BLOCK_F
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        ff = jnp.pad(ff, ((0, 0), (0, pad)))
    gcol = gate.reshape(n, 1).astype(x.dtype)

    grid = (n, xf.shape[1] // _BLOCK_F)
    out = pl.pallas_call(
        _gated_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, _BLOCK_F), lambda i, j: (i, j)),
            pl.BlockSpec((1, _BLOCK_F), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, _BLOCK_F), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=INTERPRET,
    )(gcol, xf, ff)
    return out[:, :feat].reshape(x.shape)


def _gated_fwd(x, fx, gate):
    return gated_residual(x, fx, gate), (fx, gate)


def _gated_bwd(res, g):
    fx, gate = res
    gb = gate.reshape((gate.shape[0],) + (1,) * (g.ndim - 1))
    dgate = jnp.sum(g * fx, axis=tuple(range(1, g.ndim)))
    return g, gb * g, dgate


gated_residual.defvjp(_gated_fwd, _gated_bwd)
