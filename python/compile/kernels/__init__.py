"""Layer-1 Pallas kernels + their pure-jnp oracles.

Import surface used by the L2 model and the test-suite:

    from compile.kernels import quantize, psg_select, psg_matmul, \
        matmul, gated_residual, ref

Every kernel runs under ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); numerics are identical to the ``ref`` oracles,
which pytest enforces.
"""

from . import ref  # noqa: F401
from .gated_block import gated_residual  # noqa: F401
from .matmul import matmul, vmem_bytes  # noqa: F401
from .psg import prediction_error_bound, psg_matmul, psg_select  # noqa: F401
from .quant import quantize  # noqa: F401
