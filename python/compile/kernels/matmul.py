"""Pallas tiled matmul kernel (Layer 1) — the conv/dense hot-spot.

CIFAR ResNet convolutions reach the MXU as im2col matmuls; this kernel is
the TPU rendition of the paper's FPGA conv engine (DESIGN.md
§Hardware-Adaptation): the (bm, bk) x (bk, bn) VMEM tiles play the role of
the FPGA's on-chip line buffers, and the K-grid axis is the double-buffered
HBM->VMEM streaming loop.

Grid = (M/bm, N/bn, K/bk); the K axis accumulates into the output tile,
which stays resident in VMEM across the K loop (revisiting output blocks,
the standard Pallas accumulation idiom).

Correctness oracle: :func:`ref.matmul_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

# MXU-native tiles: 128x128 output block, 128-deep K slices.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (BM, BK) x (BK, BN) partial product, accumulated over grid k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad2(v: jnp.ndarray, r: int, c: int) -> jnp.ndarray:
    pr = (-v.shape[0]) % r
    pc = (-v.shape[1]) % c
    if pr or pc:
        v = jnp.pad(v, ((0, pr), (0, pc)))
    return v


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
) -> jnp.ndarray:
    """Tiled ``a @ b`` for 2-D f32 operands (shapes padded to tiles)."""
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    m, k = a.shape
    n = b.shape[1]
    # Clamp tiles to the (padded) problem so tiny shapes stay one tile.
    bm = min(bm, -(-m // 8) * 8)
    bn = min(bn, -(-n // 8) * 8)
    bk = min(bk, -(-k // 8) * 8)
    ap = _pad2(a, bm, bk)
    bp = _pad2(b, bk, bn)

    grid = (ap.shape[0] // bm, bp.shape[1] // bn, ap.shape[1] // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), a.dtype),
        interpret=INTERPRET,
    )(ap, bp)
    return out[:m, :n]


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK, dtype_bytes: int = 4) -> int:
    """VMEM working-set estimate for one grid step (perf model, DESIGN.md).

    a-tile + b-tile + resident output tile, times 2 for double buffering of
    the streamed operands.
    """
    return dtype_bytes * (2 * (bm * bk + bk * bn) + bm * bn)
