"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for the pytest/hypothesis correctness suite
(``python/tests/test_kernels.py``): each Pallas kernel must be allclose to
its ``*_ref`` twin over randomized shapes/dtypes/bit-widths.  The L2 model
(``model.py``) calls these semantics through :mod:`kernels` — the jnp path
and the Pallas path are interchangeable by construction.

Notation follows the paper (Sec. 3.3): ``x`` is a layer input, ``g_y`` the
gradient of the layer output, ``g_w = x^T g_y`` the weight gradient,
``*_msb`` the most-significant-bits (low precision) rendition.
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric uniform fake-quantization to ``bits`` bits.

    Matches the paper's fixed-point MSB extraction: keep the top ``bits``
    bits of a symmetric fixed-point encoding whose dynamic range is the
    tensor's max-abs.  Returned values are dequantized back to f32 so the
    surrounding graph stays in one dtype (the energy ledger, not the
    numerics, accounts for the narrower datapath).

    ``bits`` counts the sign bit, i.e. levels = 2**(bits-1) - 1 per side,
    mirroring Sec. 3.3 where Delta = 2^-(B_msb - 1).
    """
    levels = float(2 ** (bits - 1) - 1)
    maxabs = jnp.max(jnp.abs(v))
    # Guard all-zero tensors: scale 1.0 quantizes zeros to zeros.
    scale = jnp.where(maxabs > 0, maxabs / levels, 1.0)
    q = jnp.clip(jnp.round(v / scale), -levels, levels)
    return q * scale


def psg_select_ref(
    g_full: jnp.ndarray, g_msb: jnp.ndarray, beta: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Predictive sign selection, Eq. (2) with the adaptive threshold.

    tau = beta * max_i |g_msb[i]| (per tensor).  Where the low-cost
    predictor is confident (|g_msb| >= tau) use its sign; otherwise fall
    back to the sign of the full-precision gradient.

    Returns ``(sign_selected, predicted_mask)`` where ``predicted_mask``
    is 1.0 where the MSB predictor was used (the paper reports this
    fraction staying >= 60% with beta = 0.05).
    """
    tau = beta * jnp.max(jnp.abs(g_msb))
    confident = jnp.abs(g_msb) >= tau
    sel = jnp.where(confident, jnp.sign(g_msb), jnp.sign(g_full))
    return sel, confident.astype(jnp.float32)


def psg_matmul_ref(
    x: jnp.ndarray,
    g_y: jnp.ndarray,
    beta: float,
    bits_x: int = 4,
    bits_gy: int = 10,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused PSG weight-gradient predictor for a linear layer.

    g_w       = x^T g_y                 (full precision, (K, N))
    g_w^msb   = Q(x)^T Q(g_y)           (MSB operands, Sec. 3.3)
    output    = Eq. (2) sign selection with tau = beta * max|g_w^msb|.

    Returns ``(sign_selected, predicted_mask)``.  This is the semantic the
    Pallas kernel ``psg.py::psg_matmul`` implements with MXU tiling.
    """
    g_w = x.T @ g_y
    g_w_msb = quantize_ref(x, bits_x).T @ quantize_ref(g_y, bits_gy)
    return psg_select_ref(g_w, g_w_msb, beta)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle for the tiled Pallas matmul kernel."""
    return a @ b


def gated_residual_ref(
    x: jnp.ndarray, fx: jnp.ndarray, gate: jnp.ndarray
) -> jnp.ndarray:
    """Per-sample gated residual merge: ``out[n] = x[n] + gate[n]*fx[n]``.

    ``gate`` has shape (N,) in [0, 1]; broadcast over the remaining dims.
    A gate of exactly 0 reproduces SLU's skipped block (identity), and the
    multiplicative form makes the block's weight gradient vanish for
    skipped samples — the backward half of the skip for free.
    """
    g = gate.reshape((gate.shape[0],) + (1,) * (x.ndim - 1))
    return x + g * fx
