"""Pallas predictive sign gradient (PSG) kernels (Layer 1).

This is the algorithm-level contribution of the paper (Sec. 3.3) as a
kernel: given the full-precision weight gradient ``g_w`` and the low-cost
MSB predictor ``g_w_msb`` (computed from 4-bit activations and 10-bit
output-gradients), select per-entry

    sel[i] = sign(g_w_msb[i])  if |g_w_msb[i]| >= tau        (predicted)
             sign(g_w[i])      otherwise                     (fallback)

with the adaptive threshold tau = beta * max_i |g_w_msb[i]| (per tensor).

Two entry points:

* :func:`psg_select` — the Eq. (2) selector as a tiled elementwise kernel
  (tau precomputed, broadcast in as a scalar block).  This is the kernel
  the AOT train-step artifacts inline for every layer's update.
* :func:`psg_matmul` — the fused end-to-end predictor for a linear layer:
  quantize operands (kernels.quant), run both the full and the MSB matmul
  through the tiled MXU kernel (kernels.matmul), then select.  This is
  the faithful "bit-level predictor embedded in the weight-grad
  contraction" rendition used by the kernel benchmarks and the pytest
  suite; the train-step graphs obtain g_w / g_w_msb through block-level
  VJPs instead (see model.py) so autodiff handles conv/BN plumbing.

Correctness oracles: ref.psg_select_ref / ref.psg_matmul_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref
from .matmul import matmul
from .quant import quantize

INTERPRET = True

_BLOCK_ROWS = 256
_BLOCK_COLS = 128


def _select_kernel(tau_ref, gf_ref, gm_ref, sel_ref, mask_ref):
    """One tile of Eq. (2): predicted sign + predictor-used mask."""
    tau = tau_ref[0, 0]
    gm = gm_ref[...]
    gf = gf_ref[...]
    confident = jnp.abs(gm) >= tau
    sel_ref[...] = jnp.where(confident, jnp.sign(gm), jnp.sign(gf))
    mask_ref[...] = confident.astype(gf.dtype)


def _as_tiles(flat: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    n = flat.shape[0]
    rows = -(-n // _BLOCK_COLS)
    pad_rows = (-rows) % _BLOCK_ROWS
    m = jnp.pad(flat, (0, (rows + pad_rows) * _BLOCK_COLS - n)).reshape(
        rows + pad_rows, _BLOCK_COLS
    )
    return m, n


@jax.jit
def psg_select(
    g_full: jnp.ndarray, g_msb: jnp.ndarray, beta
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. (2) sign selection over an arbitrary-shape gradient tensor.

    ``beta`` may be a python float or a traced scalar — the adaptive
    threshold is data-dependent either way, so the AOT train-step can
    expose beta as a runtime input (the Table-3 beta sweep runs against
    one artifact).

    Returns ``(sign_selected, predicted_mask)`` shaped like the inputs.
    Padding rows are quantitatively harmless: tau >= 0 and |0| >= tau only
    when tau == 0, and the pad region is sliced away before reshape.
    """
    assert g_full.shape == g_msb.shape
    orig_shape = g_full.shape
    gf, n = _as_tiles(g_full.reshape(-1))
    gm, _ = _as_tiles(g_msb.reshape(-1))
    tau = (
        jnp.asarray(beta, g_full.dtype) * jnp.max(jnp.abs(g_msb))
    ).reshape(1, 1).astype(g_full.dtype)

    grid = (gf.shape[0] // _BLOCK_ROWS, gf.shape[1] // _BLOCK_COLS)
    sel, mask = pl.pallas_call(
        _select_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(gf.shape, g_full.dtype),
            jax.ShapeDtypeStruct(gf.shape, g_full.dtype),
        ],
        interpret=INTERPRET,
    )(tau, gf, gm)

    sel = sel.reshape(-1)[:n].reshape(orig_shape)
    mask = mask.reshape(-1)[:n].reshape(orig_shape)
    return sel, mask


@functools.partial(jax.jit, static_argnames=("beta", "bits_x", "bits_gy"))
def psg_matmul(
    x: jnp.ndarray,
    g_y: jnp.ndarray,
    beta: float,
    bits_x: int = 4,
    bits_gy: int = 10,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused PSG weight gradient for a linear layer: all-Pallas pipeline.

    g_w = x^T g_y via the tiled MXU matmul; g_w^msb likewise from the
    quantized operands (the MSB path runs at 4/10-bit operand width — on
    real hardware this is the embedded narrow datapath the paper gets for
    free; here the energy ledger charges it at the narrow width).
    """
    g_w = matmul(x.T, g_y)
    g_w_msb = matmul(quantize(x, bits_x).T, quantize(g_y, bits_gy))
    return psg_select(g_w, g_w_msb, beta)


def prediction_error_bound(
    x: jnp.ndarray,
    g_y: jnp.ndarray,
    beta: float,
    bits_x: int = 4,
    bits_gy: int = 10,
) -> float:
    """Loose empirical rendition of the Eq. (3) failure bound.

    Used by the test-suite to check the *direction* of the guarantee: the
    measured sign-flip rate of the predictor (vs. the true full-precision
    sign) must lie below the bound; the bound must shrink as predictor
    precision grows.  Delta = 2^-(B_msb - 1) per Sec. 3.3, and E1/E2 are
    estimated from the operand second moments with the adaptive tau.
    """
    g_w_msb = _ref.quantize_ref(x, bits_x).T @ _ref.quantize_ref(g_y, bits_gy)
    tau = beta * jnp.max(jnp.abs(g_w_msb))
    tau = jnp.maximum(tau, 1e-12)
    d_x = 2.0 ** -(bits_x - 1)
    d_gy = 2.0 ** -(bits_gy - 1)
    # Scale-free operand energies (data range normalized to [-1, 1] as in
    # the appendix discussion).
    xs = x / jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    gs = g_y / jnp.maximum(jnp.max(jnp.abs(g_y)), 1e-12)
    taus = tau / jnp.maximum(jnp.max(jnp.abs(g_w_msb)), 1e-12)
    e1 = jnp.sum(gs**2) / (12.0 * taus**2)
    e2 = jnp.sum(xs**2) / (12.0 * taus**2)
    return float(d_x**2 * e1 + d_gy**2 * e2)
