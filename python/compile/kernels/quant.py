"""Pallas fixed-point fake-quantization kernel (Layer 1).

Implements the MSB extraction of Sec. 3.3: symmetric uniform quantization
to ``bits`` bits with a per-tensor dynamic scale.  The scale (a cheap
global max-abs reduction) is computed outside the kernel and broadcast in
as a (1, 1) scalar block; the kernel itself is a tiled elementwise
round/clip/rescale — on TPU this is a pure VPU op streaming one VMEM tile
at a time, no MXU involvement.

Correctness oracle: :func:`ref.quantize_ref` (pytest + hypothesis sweep).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
# custom-calls, so kernels lower to plain HLO (see DESIGN.md).
INTERPRET = True

# VPU-friendly tile: 8 sublanes x 128 lanes is the f32 native tile; we use
# a (256, 128) block so each grid step moves 128KiB through VMEM.
_BLOCK_ROWS = 256
_BLOCK_COLS = 128


def _quant_kernel(scale_ref, levels_ref, v_ref, o_ref):
    """One (block_rows, block_cols) tile: q = clip(round(v/s), ±L) * s."""
    s = scale_ref[0, 0]
    levels = levels_ref[0, 0]
    v = v_ref[...]
    q = jnp.clip(jnp.round(v / s), -levels, levels)
    o_ref[...] = q * s


def _pad_to(v: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr = (-v.shape[0]) % rows
    pc = (-v.shape[1]) % cols
    if pr or pc:
        v = jnp.pad(v, ((0, pr), (0, pc)))
    return v


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantize ``v`` to ``bits`` bits (Pallas tiled elementwise).

    Accepts any-rank input; internally flattened to 2-D tiles.  Matches
    :func:`ref.quantize_ref` exactly (same rounding, same zero-tensor
    guard).

    Differentiation: straight-through estimator (identity gradient), the
    standard rule for fake-quant in low-precision training [13, 15] — the
    Pallas call itself has no autodiff rule, and round() would have a
    zero gradient anyway.
    """
    orig_shape = v.shape
    flat = v.reshape(-1)
    # Lay the flat vector out as a (rows, _BLOCK_COLS) matrix.
    n = flat.shape[0]
    rows = -(-n // _BLOCK_COLS)
    m = _pad_to(
        jnp.pad(flat, (0, rows * _BLOCK_COLS - n)).reshape(rows, _BLOCK_COLS),
        _BLOCK_ROWS,
        _BLOCK_COLS,
    )

    levels = float(2 ** (bits - 1) - 1)
    maxabs = jnp.max(jnp.abs(v))
    scale = jnp.where(maxabs > 0, maxabs / levels, 1.0).reshape(1, 1)
    levels_arr = jnp.full((1, 1), levels, dtype=v.dtype)

    grid = (m.shape[0] // _BLOCK_ROWS, m.shape[1] // _BLOCK_COLS)
    out = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # scale, resident
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # levels, resident
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(m.shape, v.dtype),
        interpret=INTERPRET,
    )(scale.astype(v.dtype), levels_arr, m)

    return out.reshape(-1)[:n].reshape(orig_shape)


def _quantize_fwd(v, bits):
    return quantize(v, bits), None


def _quantize_bwd(bits, _res, g):
    return (g,)  # straight-through


quantize.defvjp(_quantize_fwd, _quantize_bwd)
