"""Layer-2: E²-Train train/eval steps with a hand-rolled block-level
backward pass.

Why manual backprop?  The paper's two model/algorithm-level techniques
both live *inside* the backward pass:

* SLU (Sec. 3.2) skips blocks in **both** the forward and backward pass —
  the per-sample gate multiplies the residual branch, so a skipped
  sample's branch contributes neither activations forward nor weight
  gradients backward; block-level VJPs make that structure explicit and
  let the rust coordinator's block-chained mode drop whole executables.
* PSG (Sec. 3.3) replaces each layer's weight gradient with a predicted
  sign computed from MSB-quantized operands.  We intercept each block's
  VJP, re-run it with 4-bit activations and a 10-bit output-gradient to
  obtain g_w^msb, and select per Eq. (2) via the Pallas psg_select kernel.

The step builders return *flat-list* functions: rust feeds a
manifest-ordered list of buffers and receives one back.  See aot.py for
the manifest format.

One train-step artifact per (arch, method); methods are declared as
:class:`MethodSpec` values in :data:`METHODS`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import archs as A
from . import gates as G
from . import layers as L
from .kernels import psg_select, quantize

Params = Dict[str, jnp.ndarray]

# Parameter names receiving sign-style updates under sign/psg rules
# (conv + fc weights).  BN scale/bias, biases and gate parameters always
# take plain SGD(+momentum) — sign updates on normalization parameters
# destabilize training and the paper's PSG targets *weight* gradients.
_WEIGHT_SUFFIXES = (".conv", ".conv1", ".conv2", ".down", ".expand", ".dw", ".project")


def is_weight(name: str) -> bool:
    return (
        name == "head.w"
        or any(name.endswith(s) for s in _WEIGHT_SUFFIXES)
    )


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Declarative description of one training method variant."""

    name: str
    qbits_act: Optional[int] = None  # fake-quant of activations/weights fwd
    qbits_grad: Optional[int] = None  # fake-quant of the streamed gradient
    update: str = "sgd"  # sgd | sign | psg
    gating: str = "none"  # none | learned | mask
    alpha: float = 0.0  # Eq. (1) FLOPs-regularizer weight
    beta: float = 0.05  # PSG adaptive-threshold ratio
    momentum: float = 0.9
    weight_decay: float = 1e-4
    psg_bits_x: int = 4
    psg_bits_gy: int = 10
    # Fine-tuning baseline (Sec. 4.5 option 1): only the FC head is
    # trained; the trunk is frozen and no trunk backward runs.
    head_only: bool = False


# The method zoo: the paper's baselines (Tables 2-4) + E2-Train variants.
METHODS: Dict[str, MethodSpec] = {
    # 32-bit floating point SGD — the paper's accuracy/energy anchor.
    "sgd32": MethodSpec("sgd32"),
    # 8-bit fixed-point training of Banner et al. [15]: 8-bit fwd, but
    # 32-bit gradients — the paper attributes [15]'s limited (~39%)
    # energy saving exactly to those full-precision gradients (Sec. 4.4).
    "fixed8": MethodSpec("fixed8", qbits_act=8, qbits_grad=None),
    # SignSGD [20]: full-precision gradient, sign-only update.
    "signsgd": MethodSpec(
        "signsgd", update="sign", momentum=0.0, weight_decay=5e-4
    ),
    # PSG (Sec. 3.3): 8/16-bit datapath + predictive sign from 4/10-bit
    # MSB operands with adaptive threshold.
    "psg": MethodSpec(
        "psg",
        qbits_act=8,
        qbits_grad=16,
        update="psg",
        momentum=0.0,
        weight_decay=5e-4,
    ),
    # SLU (Sec. 3.2): learned RNN gates + FLOPs regularizer, SGD update.
    "slu": MethodSpec("slu", gating="learned", alpha=1.0),
    # Stochastic depth [66] baseline: per-batch random block masks fed by
    # the coordinator (which owns the survival schedule p_L).
    "sd": MethodSpec("sd", gating="mask"),
    # The full E2-Train stack: SLU + PSG (+ SMD at the coordinator level).
    "e2train": MethodSpec(
        "e2train",
        qbits_act=8,
        qbits_grad=16,
        update="psg",
        gating="learned",
        alpha=1.0,
        momentum=0.0,
        weight_decay=5e-4,
    ),
    # Last-FC-layer fine-tuning baseline of the Sec. 4.5 experiment.
    "headft": MethodSpec("headft", head_only=True),
}


# ==========================================================================
# Spec plumbing — the flat AOT interface
# ==========================================================================

@dataclasses.dataclass
class IoSpec:
    name: str
    role: str  # param | mom | state | data | scalar | mask | out_*
    shape: Tuple[int, ...]
    dtype: str
    init: str = ""


def build_io(
    arch: A.Arch, method: MethodSpec, batch: int
) -> Tuple[List[IoSpec], List[IoSpec], Dict[str, L.Spec]]:
    """Ordered input/output specs for a train-step artifact."""
    pspecs = dict(arch.param_specs())
    if method.gating == "learned":
        pspecs.update(G.gate_specs([b.in_ch for b in arch.gated_blocks()]))
    sspecs = arch.bn_state_specs()

    ins: List[IoSpec] = []
    for n, (shape, init) in pspecs.items():
        ins.append(IoSpec(n, "param", shape, "f32", init))
    for n, (shape, init) in pspecs.items():
        ins.append(IoSpec(f"mom.{n}", "mom", shape, "f32", "zeros"))
    for n, (shape, init) in sspecs.items():
        ins.append(IoSpec(n, "state", shape, "f32", init))
    ins.append(IoSpec("x", "data", (batch, arch.image_size, arch.image_size, 3), "f32"))
    ins.append(IoSpec("y", "data", (batch,), "i32"))
    ins.append(IoSpec("lr", "scalar", (), "f32"))
    # Runtime-tunable hyper-parameters: the Fig. 4 / Table 3 sweeps vary
    # the FLOPs-regularizer weight and the PSG threshold without
    # re-lowering artifacts.
    if method.gating == "learned":
        ins.append(IoSpec("alpha", "scalar", (), "f32"))
    if method.update == "psg":
        ins.append(IoSpec("beta", "scalar", (), "f32"))
    if method.gating == "mask":
        ins.append(IoSpec("mask", "mask", (len(arch.gated_blocks()),), "f32"))

    outs: List[IoSpec] = []
    for n, (shape, _) in pspecs.items():
        outs.append(IoSpec(n, "out_param", shape, "f32"))
    for n, (shape, _) in pspecs.items():
        outs.append(IoSpec(f"mom.{n}", "out_mom", shape, "f32"))
    for n, (shape, _) in sspecs.items():
        outs.append(IoSpec(n, "out_state", shape, "f32"))
    outs.append(IoSpec("loss", "out_metric", (), "f32"))
    outs.append(IoSpec("correct", "out_metric", (), "f32"))
    if method.gating != "none":
        outs.append(
            IoSpec("gate_fracs", "out_metric", (len(arch.gated_blocks()),), "f32")
        )
    if method.update == "psg":
        outs.append(IoSpec("psg_frac", "out_metric", (), "f32"))
    return ins, outs, pspecs


def _fix_dtype(spec: IoSpec) -> str:
    return spec.dtype if spec.dtype in ("f32", "i32") else "f32"


# ==========================================================================
# Train step
# ==========================================================================

def build_train_step(
    arch: A.Arch, method: MethodSpec, batch: int
) -> Tuple[Callable, List[IoSpec], List[IoSpec]]:
    """Returns ``(step_fn, input_specs, output_specs)``.

    ``step_fn(*flat_inputs) -> tuple(flat_outputs)`` in manifest order.
    """
    ins, outs, pspecs = build_io(arch, method, batch)
    sspecs = arch.bn_state_specs()
    pnames = list(pspecs.keys())
    snames = list(sspecs.keys())
    gated = arch.gated_blocks()
    gated_names = {b.name for b in gated}
    flop_fracs = arch.gated_flop_fracs()

    def step(*flat):
        it = iter(flat)
        params = {n: next(it) for n in pnames}
        mom = {n: next(it) for n in pnames}
        bn_state = {n: next(it) for n in snames}
        x = next(it)
        y = next(it)
        lr = next(it)
        alpha = next(it) if method.gating == "learned" else method.alpha
        beta = next(it) if method.update == "psg" else method.beta
        sd_mask = next(it) if method.gating == "mask" else None
        n = x.shape[0]
        ones = jnp.ones((n,), jnp.float32)

        # ---------------- Phase A: forward, gates interleaved ------------
        vjps = []  # per block: (vjp_fn, block, gate used)
        bn_batch: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        pooled_sg: List[jnp.ndarray] = []  # gate inputs (stop-grad)
        masks: List[jnp.ndarray] = []  # straight-through masks, per gated
        block_inputs: List[jnp.ndarray] = []
        gi = 0
        h = jnp.zeros((n, L.GATE_DIM), jnp.float32)
        c = jnp.zeros((n, L.GATE_DIM), jnp.float32)
        a = x
        for blk in arch.blocks:
            bp = {k: params[k] for k in blk.specs}
            gate = ones
            if blk.gateable and method.gating == "learned":
                pooled = jax.lax.stop_gradient(L.global_avg_pool(a))
                prob, h, c = G.gate_step(params, pooled, h, c)
                # Forward uses the hard decision; the straight-through
                # correction is attached in the gate-backward phase.
                gate = (prob > 0.5).astype(jnp.float32)
                pooled_sg.append(pooled)
                masks.append(gate)
                gi += 1
            elif blk.gateable and method.gating == "mask":
                gate = sd_mask[gi] * ones
                masks.append(gate)
                gi += 1
            block_inputs.append(a)
            (out, stats), vjp_fn = _vjp_block(blk, bp, a, gate)
            bn_batch.update(stats)
            vjps.append((vjp_fn, blk, gate))
            a = out

        # ---------------- Phase B: head + loss ---------------------------
        hp = {k: params[k] for k in ("head.w", "head.b")}

        def head_loss(hp_, feat_):
            logits = arch.head_apply(hp_, feat_)
            loss_, correct_ = L.softmax_xent(logits, y)
            return loss_, correct_

        (loss, head_vjp_fn, correct) = jax.vjp(head_loss, hp, a, has_aux=True)
        ghp, gfeat = head_vjp_fn(jnp.float32(1.0))

        grads: Dict[str, jnp.ndarray] = dict(ghp)
        msb_grads: Dict[str, jnp.ndarray] = {}
        if method.update == "psg":
            # MSB predictor for the FC head: g_w = pooled^T dlogits, so the
            # predictor is Q(pooled, 4)^T Q(dlogits, 10) — exactly the
            # psg_matmul pipeline (Sec. 3.3) on the head's operands.
            pooled = L.global_avg_pool(a)
            logits = arch.head_apply(hp, a)
            dlogits = (jax.nn.softmax(logits) - jax.nn.one_hot(y, logits.shape[-1])) / n
            msb_grads["head.w"] = (
                quantize(pooled, method.psg_bits_x).T
                @ quantize(dlogits, method.psg_bits_gy)
            )
        gate_cots: List[jnp.ndarray] = [None] * len(masks)

        # ---------------- Phase C: block backward (reversed) -------------
        # head-only fine-tuning: the trunk is frozen, no trunk backward.
        blocks_bwd = [] if method.head_only else list(
            zip(reversed(vjps), reversed(block_inputs))
        )
        g = gfeat
        gi = len(masks)
        for (vjp_fn, blk, gate), a_in in blocks_bwd:
            if method.qbits_grad is not None:
                g = quantize(g, method.qbits_grad)
            gp_b, ga, ggate = vjp_fn(g)
            if blk.gateable and method.gating != "none":
                gi -= 1
                gate_cots[gi] = ggate
            if method.update == "psg":
                # MSB predictor: re-run the block VJP with 4-bit input
                # activations and a 10-bit output gradient (Sec. 3.3).
                bp = {k: params[k] for k in blk.specs}
                a_q = quantize(a_in, method.psg_bits_x)
                (_, _), vjp_q = _vjp_block(blk, bp, a_q, gate)
                gq_b, _, _ = vjp_q(quantize(g, method.psg_bits_gy))
                for k, v in gq_b.items():
                    if is_weight(k):
                        msb_grads[k] = v
            grads.update(gp_b)
            g = ga

        # ---------------- Phase D: gate backward -------------------------
        if method.gating == "learned" and masks:
            def traj_loss(gp_):
                probs = G.trajectory(gp_, pooled_sg)
                total = jnp.float32(0.0)
                for j, p in enumerate(probs):
                    cot = jax.lax.stop_gradient(gate_cots[j])
                    # Straight-through: dL/dprob = dL/dmask; plus Eq. (1)
                    # FLOPs regularizer alpha * sum_b frac_b * mean(prob_b).
                    total = total + jnp.vdot(cot, p)
                    total = total + alpha * flop_fracs[j] * jnp.mean(p)
                return total

            gnames = [k for k in pnames if k.startswith("gate.")]
            gp = {k: params[k] for k in gnames}
            _, gate_vjp = jax.vjp(traj_loss, gp)
            (ggate_params,) = gate_vjp(jnp.float32(1.0))
            grads.update(ggate_params)

        # ---------------- Phase E: parameter update ----------------------
        new_params: Dict[str, jnp.ndarray] = {}
        new_mom: Dict[str, jnp.ndarray] = {}
        psg_fracs: List[jnp.ndarray] = []
        for k in pnames:
            w = params[k]
            gk = grads.get(k)
            if gk is None:  # parameter untouched this step
                new_params[k] = w
                new_mom[k] = mom[k]
                continue
            if method.update in ("sign", "psg") and is_weight(k):
                gk = gk + method.weight_decay * w
                if method.update == "psg":
                    sel, pmask = psg_select(gk, msb_grads[k], beta)
                    psg_fracs.append(jnp.mean(pmask))
                    upd = sel
                else:
                    upd = jnp.sign(gk)
                new_params[k] = w - lr * upd
                new_mom[k] = mom[k]
            else:
                gk = gk + method.weight_decay * w
                v = method.momentum * mom[k] + gk
                new_params[k] = w - lr * v
                new_mom[k] = v

        # ---------------- BN running-stat EMA ----------------------------
        new_state: Dict[str, jnp.ndarray] = {}
        for prefix, (m_, v_) in bn_batch.items():
            new_state[f"{prefix}.rmean"] = L.ema(bn_state[f"{prefix}.rmean"], m_)
            new_state[f"{prefix}.rvar"] = L.ema(bn_state[f"{prefix}.rvar"], v_)
        for sname in snames:
            new_state.setdefault(sname, bn_state[sname])

        out_flat: List[jnp.ndarray] = []
        out_flat += [new_params[k] for k in pnames]
        out_flat += [new_mom[k] for k in pnames]
        out_flat += [new_state[k] for k in snames]
        out_flat += [loss, correct]
        if method.gating != "none":
            out_flat.append(jnp.stack([jnp.mean(m) for m in masks]))
        if method.update == "psg":
            out_flat.append(jnp.mean(jnp.stack(psg_fracs)))
        return tuple(out_flat)

    return step, ins, outs


def _vjp_block(blk: A.BlockDef, bp: Params, a: jnp.ndarray, gate: jnp.ndarray):
    """jax.vjp over a block's train apply, splitting out the BN-stats aux."""
    primal, vjp_fn, stats = jax.vjp(blk.apply_train, bp, a, gate, has_aux=True)
    return (primal, stats), vjp_fn


# ==========================================================================
# Eval step
# ==========================================================================

def build_eval_step(
    arch: A.Arch, method: MethodSpec, batch: int
) -> Tuple[Callable, List[IoSpec], List[IoSpec]]:
    """Inference-mode step: running BN stats, hard gates (no ST)."""
    pspecs = dict(arch.param_specs())
    if method.gating == "learned":
        pspecs.update(G.gate_specs([b.in_ch for b in arch.gated_blocks()]))
    sspecs = arch.bn_state_specs()
    pnames = list(pspecs.keys())
    snames = list(sspecs.keys())

    ins: List[IoSpec] = []
    for n_, (shape, init) in pspecs.items():
        ins.append(IoSpec(n_, "param", shape, "f32", init))
    for n_, (shape, init) in sspecs.items():
        ins.append(IoSpec(n_, "state", shape, "f32", init))
    ins.append(IoSpec("x", "data", (batch, arch.image_size, arch.image_size, 3), "f32"))
    ins.append(IoSpec("y", "data", (batch,), "i32"))

    outs = [
        IoSpec("loss", "out_metric", (), "f32"),
        IoSpec("correct", "out_metric", (), "f32"),
        IoSpec("correct5", "out_metric", (), "f32"),
    ]
    if method.gating == "learned":
        outs.append(
            IoSpec("gate_fracs", "out_metric", (len(arch.gated_blocks()),), "f32")
        )

    def step(*flat):
        it = iter(flat)
        params = {n_: next(it) for n_ in pnames}
        bn_state = {n_: next(it) for n_ in snames}
        x = next(it)
        y = next(it)
        n = x.shape[0]
        ones = jnp.ones((n,), jnp.float32)
        h = jnp.zeros((n, L.GATE_DIM), jnp.float32)
        c = jnp.zeros((n, L.GATE_DIM), jnp.float32)
        fracs = []
        a = x
        for blk in arch.blocks:
            bp = {k: params[k] for k in blk.specs}
            bs = {k: bn_state[k] for k in blk.bn_state_specs()}
            gate = ones
            if blk.gateable and method.gating == "learned":
                prob, h, c = G.gate_step(params, L.global_avg_pool(a), h, c)
                gate = (prob > 0.5).astype(jnp.float32)
                fracs.append(jnp.mean(gate))
            a = blk.apply_eval(bp, bs, a, gate)
        logits = arch.head_apply(params, a)
        loss, correct = L.softmax_xent(logits, y)
        # top-5 via ranks (lax.top_k lowers to an HLO `topk` attribute the
        # xla_extension 0.5.1 text parser rejects): the label is in the
        # top-k iff fewer than k logits strictly exceed it.  Negative
        # labels are eval-tail padding: masked out, never a top-k hit.
        k = min(5, logits.shape[-1])
        valid = y >= 0
        safe_y = jnp.where(valid, y, 0)
        ly = logits[jnp.arange(logits.shape[0]), safe_y]
        rank = jnp.sum((logits > ly[:, None]).astype(jnp.int32), axis=1)
        correct5 = jnp.sum(((rank < k) & valid).astype(jnp.float32))
        out = [loss, correct, correct5]
        if method.gating == "learned":
            out.append(jnp.stack(fracs))
        return tuple(out)

    return step, ins, outs
