"""Layer-2 architectures as explicit block lists.

The paper evaluates CIFAR ResNets (ResNet-38/74/110 — the 6n+2 family) and
MobileNetV2.  Both are expressed here as a list of :class:`BlockDef`s — a
uniform trunk abstraction that the train-step builder (model.py) walks
forward and *backward by hand*, which is what lets SLU skip blocks in both
passes and lets PSG intercept each block's weight gradients (Sec. 3.2/3.3).

A BlockDef's ``apply(params, x, gate)`` is a pure function suitable for
``jax.vjp(..., has_aux=True)``; ``aux`` carries the batch-norm batch
statistics so the EMA update happens outside the VJP.

FLOPs here are MACs — the unit the paper's C(W, G) regularizer and the
rust energy ledger both consume; the manifest exports them per block.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .kernels import gated_residual, quantize

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass
class BlockDef:
    """One trunk block: parameters, pure apply fns, and cost metadata."""

    name: str
    specs: Dict[str, L.Spec]
    bn_prefixes: List[str]
    gateable: bool
    flops: int
    in_ch: int
    out_ch: int
    in_hw: int
    # train apply: (params, x, gate(N,)) -> (out, bn_stats dict)
    apply_train: Callable = None
    # eval apply: (params, bn_state, x, gate(N,)) -> out
    apply_eval: Callable = None

    def bn_state_specs(self) -> Dict[str, L.Spec]:
        out = {}
        for p in self.bn_prefixes:
            c = self.specs[f"{p}.scale"][0]
            out[f"{p}.rmean"] = (c, "zeros")
            out[f"{p}.rvar"] = (c, "ones")
        return out


@dataclasses.dataclass
class Arch:
    """A full trunk + head: what one AOT artifact family is built from."""

    name: str
    blocks: List[BlockDef]  # blocks[0] is the stem
    head_specs: Dict[str, L.Spec]
    head_flops: int
    num_classes: int
    image_size: int
    feat_ch: int

    # -- aggregate views used by model.py / aot.py ------------------------
    def param_specs(self) -> Dict[str, L.Spec]:
        out: Dict[str, L.Spec] = {}
        for b in self.blocks:
            out.update(b.specs)
        out.update(self.head_specs)
        return out

    def bn_state_specs(self) -> Dict[str, L.Spec]:
        out: Dict[str, L.Spec] = {}
        for b in self.blocks:
            out.update(b.bn_state_specs())
        return out

    def gated_blocks(self) -> List[BlockDef]:
        return [b for b in self.blocks if b.gateable]

    def total_flops(self) -> int:
        return sum(b.flops for b in self.blocks) + self.head_flops

    def gated_flop_fracs(self) -> List[float]:
        tot = float(self.total_flops())
        return [b.flops / tot for b in self.gated_blocks()]

    def head_apply(self, params: Params, feat: jnp.ndarray) -> jnp.ndarray:
        pooled = L.global_avg_pool(feat)
        return L.dense(pooled, params["head.w"], params["head.b"])


def _maybe_q(v: jnp.ndarray, bits: Optional[int]) -> jnp.ndarray:
    return v if bits is None else quantize(v, bits)


# ==========================================================================
# ResNet (CIFAR 6n+2 family: resnet8 n=1 ... resnet110 n=18)
# ==========================================================================

def _basic_block(
    name: str,
    in_ch: int,
    out_ch: int,
    stride: int,
    in_hw: int,
    qbits: Optional[int],
) -> BlockDef:
    """Post-activation basic residual block; gate multiplies the branch.

    gate == 0 collapses the block to identity for that sample: the
    shortcut is the (already non-negative) input, so the trailing ReLU is
    a no-op — SLU's skipped block in both passes (the gate factor also
    zeroes the branch weight gradient per sample).
    """
    down = stride != 1 or in_ch != out_ch
    specs: Dict[str, L.Spec] = {
        f"{name}.conv1": ((3, 3, in_ch, out_ch), "he"),
        f"{name}.bn1.scale": ((out_ch,), "ones"),
        f"{name}.bn1.bias": ((out_ch,), "zeros"),
        f"{name}.conv2": ((3, 3, out_ch, out_ch), "he"),
        f"{name}.bn2.scale": ((out_ch,), "ones"),
        f"{name}.bn2.bias": ((out_ch,), "zeros"),
    }
    bn_prefixes = [f"{name}.bn1", f"{name}.bn2"]
    if down:
        specs[f"{name}.down"] = ((1, 1, in_ch, out_ch), "he")
        specs[f"{name}.down_bn.scale"] = ((out_ch,), "ones")
        specs[f"{name}.down_bn.bias"] = ((out_ch,), "zeros")
        bn_prefixes.append(f"{name}.down_bn")

    def branch_train(p: Params, x: jnp.ndarray):
        stats = {}
        h = L.conv2d(_maybe_q(x, qbits), _maybe_q(p[f"{name}.conv1"], qbits), stride)
        h, m, v = L.bn_train(h, p[f"{name}.bn1.scale"], p[f"{name}.bn1.bias"])
        stats[f"{name}.bn1"] = (m, v)
        h = L.relu(h)
        h = L.conv2d(_maybe_q(h, qbits), _maybe_q(p[f"{name}.conv2"], qbits), 1)
        h, m, v = L.bn_train(h, p[f"{name}.bn2.scale"], p[f"{name}.bn2.bias"])
        stats[f"{name}.bn2"] = (m, v)
        return h, stats

    def apply_train(p: Params, x: jnp.ndarray, gate: jnp.ndarray):
        h, stats = branch_train(p, x)
        if down:
            sc = L.conv2d(_maybe_q(x, qbits), _maybe_q(p[f"{name}.down"], qbits), stride)
            sc, m, v = L.bn_train(
                sc, p[f"{name}.down_bn.scale"], p[f"{name}.down_bn.bias"]
            )
            stats[f"{name}.down_bn"] = (m, v)
            out = L.relu(sc + h)  # downsample blocks are never gated
        else:
            out = L.relu(gated_residual(x, h, gate))
        return out, stats

    def apply_eval(p: Params, bn: Params, x: jnp.ndarray, gate: jnp.ndarray):
        def ebn(prefix, t):
            return L.bn_eval(
                t,
                p[f"{prefix}.scale"],
                p[f"{prefix}.bias"],
                bn[f"{prefix}.rmean"],
                bn[f"{prefix}.rvar"],
            )

        h = L.conv2d(_maybe_q(x, qbits), _maybe_q(p[f"{name}.conv1"], qbits), stride)
        h = L.relu(ebn(f"{name}.bn1", h))
        h = L.conv2d(_maybe_q(h, qbits), _maybe_q(p[f"{name}.conv2"], qbits), 1)
        h = ebn(f"{name}.bn2", h)
        if down:
            sc = L.conv2d(_maybe_q(x, qbits), _maybe_q(p[f"{name}.down"], qbits), stride)
            sc = ebn(f"{name}.down_bn", sc)
            return L.relu(sc + h)
        return L.relu(gated_residual(x, h, gate))

    flops = L.conv_flops(in_hw, in_hw, 3, 3, in_ch, out_ch, stride)
    flops += L.conv_flops(
        -(-in_hw // stride), -(-in_hw // stride), 3, 3, out_ch, out_ch, 1
    )
    if down:
        flops += L.conv_flops(in_hw, in_hw, 1, 1, in_ch, out_ch, stride)

    return BlockDef(
        name=name,
        specs=specs,
        bn_prefixes=bn_prefixes,
        gateable=not down,
        flops=flops,
        in_ch=in_ch,
        out_ch=out_ch,
        in_hw=in_hw,
        apply_train=apply_train,
        apply_eval=apply_eval,
    )


def _stem_block(
    name: str, out_ch: int, hw: int, qbits: Optional[int]
) -> BlockDef:
    specs = {
        f"{name}.conv": ((3, 3, 3, out_ch), "he"),
        f"{name}.bn.scale": ((out_ch,), "ones"),
        f"{name}.bn.bias": ((out_ch,), "zeros"),
    }

    def apply_train(p: Params, x: jnp.ndarray, gate: jnp.ndarray):
        h = L.conv2d(_maybe_q(x, qbits), _maybe_q(p[f"{name}.conv"], qbits), 1)
        h, m, v = L.bn_train(h, p[f"{name}.bn.scale"], p[f"{name}.bn.bias"])
        return L.relu(h), {f"{name}.bn": (m, v)}

    def apply_eval(p: Params, bn: Params, x: jnp.ndarray, gate: jnp.ndarray):
        h = L.conv2d(_maybe_q(x, qbits), _maybe_q(p[f"{name}.conv"], qbits), 1)
        h = L.bn_eval(
            h,
            p[f"{name}.bn.scale"],
            p[f"{name}.bn.bias"],
            bn[f"{name}.bn.rmean"],
            bn[f"{name}.bn.rvar"],
        )
        return L.relu(h)

    return BlockDef(
        name=name,
        specs=specs,
        bn_prefixes=[f"{name}.bn"],
        gateable=False,
        flops=L.conv_flops(hw, hw, 3, 3, 3, out_ch, 1),
        in_ch=3,
        out_ch=out_ch,
        in_hw=hw,
        apply_train=apply_train,
        apply_eval=apply_eval,
    )


def resnet(
    n: int,
    num_classes: int,
    image_size: int = 32,
    width: float = 1.0,
    qbits: Optional[int] = None,
) -> Arch:
    """CIFAR ResNet-(6n+2): resnet8 n=1, resnet20 n=3, resnet38 n=6,
    resnet74 n=12, resnet110 n=18."""
    chans = [max(4, int(round(c * width))) for c in (16, 32, 64)]
    blocks: List[BlockDef] = [_stem_block("stem", chans[0], image_size, qbits)]
    in_ch, hw = chans[0], image_size
    for s, ch in enumerate(chans):
        for b in range(n):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = _basic_block(
                f"s{s}b{b}", in_ch, ch, stride, hw, qbits
            )
            blocks.append(blk)
            in_ch = ch
            hw = -(-hw // stride)
    head_specs = {
        "head.w": ((in_ch, num_classes), "he"),
        "head.b": ((num_classes,), "zeros"),
    }
    return Arch(
        name=f"resnet{6*n+2}",
        blocks=blocks,
        head_specs=head_specs,
        head_flops=in_ch * num_classes,
        num_classes=num_classes,
        image_size=image_size,
        feat_ch=in_ch,
    )


# ==========================================================================
# MobileNetV2 (CIFAR variant)
# ==========================================================================

def _dwconv(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Depthwise 3x3; w is HWIO with I=1, O=C (feature_group_count=C)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        feature_group_count=x.shape[-1],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _inverted_residual(
    name: str,
    in_ch: int,
    out_ch: int,
    stride: int,
    expand: int,
    in_hw: int,
    qbits: Optional[int],
) -> BlockDef:
    """MobileNetV2 inverted residual with linear bottleneck; gated only
    when the identity skip exists (stride 1, in_ch == out_ch)."""
    mid = in_ch * expand
    skip = stride == 1 and in_ch == out_ch
    specs: Dict[str, L.Spec] = {}
    bn_prefixes: List[str] = []
    if expand != 1:
        specs[f"{name}.expand"] = ((1, 1, in_ch, mid), "he")
        specs[f"{name}.bn_e.scale"] = ((mid,), "ones")
        specs[f"{name}.bn_e.bias"] = ((mid,), "zeros")
        bn_prefixes.append(f"{name}.bn_e")
    specs[f"{name}.dw"] = ((3, 3, 1, mid), "he")
    specs[f"{name}.bn_d.scale"] = ((mid,), "ones")
    specs[f"{name}.bn_d.bias"] = ((mid,), "zeros")
    specs[f"{name}.project"] = ((1, 1, mid, out_ch), "he")
    specs[f"{name}.bn_p.scale"] = ((out_ch,), "ones")
    specs[f"{name}.bn_p.bias"] = ((out_ch,), "zeros")
    bn_prefixes += [f"{name}.bn_d", f"{name}.bn_p"]

    def branch_train(p: Params, x: jnp.ndarray):
        stats = {}
        h = x
        if expand != 1:
            h = L.conv2d(_maybe_q(h, qbits), _maybe_q(p[f"{name}.expand"], qbits), 1)
            h, m, v = L.bn_train(h, p[f"{name}.bn_e.scale"], p[f"{name}.bn_e.bias"])
            stats[f"{name}.bn_e"] = (m, v)
            h = L.relu6(h)
        h = _dwconv(_maybe_q(h, qbits), _maybe_q(p[f"{name}.dw"], qbits), stride)
        h, m, v = L.bn_train(h, p[f"{name}.bn_d.scale"], p[f"{name}.bn_d.bias"])
        stats[f"{name}.bn_d"] = (m, v)
        h = L.relu6(h)
        h = L.conv2d(_maybe_q(h, qbits), _maybe_q(p[f"{name}.project"], qbits), 1)
        h, m, v = L.bn_train(h, p[f"{name}.bn_p.scale"], p[f"{name}.bn_p.bias"])
        stats[f"{name}.bn_p"] = (m, v)
        return h, stats

    def apply_train(p: Params, x: jnp.ndarray, gate: jnp.ndarray):
        h, stats = branch_train(p, x)
        out = gated_residual(x, h, gate) if skip else h
        return out, stats

    def apply_eval(p: Params, bn: Params, x: jnp.ndarray, gate: jnp.ndarray):
        def ebn(prefix, t):
            return L.bn_eval(
                t,
                p[f"{prefix}.scale"],
                p[f"{prefix}.bias"],
                bn[f"{prefix}.rmean"],
                bn[f"{prefix}.rvar"],
            )

        h = x
        if expand != 1:
            h = L.conv2d(_maybe_q(h, qbits), _maybe_q(p[f"{name}.expand"], qbits), 1)
            h = L.relu6(ebn(f"{name}.bn_e", h))
        h = _dwconv(_maybe_q(h, qbits), _maybe_q(p[f"{name}.dw"], qbits), stride)
        h = L.relu6(ebn(f"{name}.bn_d", h))
        h = L.conv2d(_maybe_q(h, qbits), _maybe_q(p[f"{name}.project"], qbits), 1)
        h = ebn(f"{name}.bn_p", h)
        return gated_residual(x, h, gate) if skip else h

    out_hw = -(-in_hw // stride)
    flops = 0
    if expand != 1:
        flops += L.conv_flops(in_hw, in_hw, 1, 1, in_ch, mid, 1)
    flops += out_hw * out_hw * 9 * mid  # depthwise
    flops += L.conv_flops(out_hw, out_hw, 1, 1, mid, out_ch, 1)

    return BlockDef(
        name=name,
        specs=specs,
        bn_prefixes=bn_prefixes,
        gateable=skip,
        flops=flops,
        in_ch=in_ch,
        out_ch=out_ch,
        in_hw=in_hw,
        apply_train=apply_train,
        apply_eval=apply_eval,
    )


# (t, c, n, s) for CIFAR (strides thinned vs. ImageNet: 32x32 input)
_MBV2_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 1),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2(
    num_classes: int,
    image_size: int = 32,
    width: float = 1.0,
    qbits: Optional[int] = None,
    cfg: Optional[List[Tuple[int, int, int, int]]] = None,
) -> Arch:
    cfg = cfg if cfg is not None else _MBV2_CFG
    stem_ch = max(8, int(round(32 * width)))
    blocks: List[BlockDef] = [_stem_block("stem", stem_ch, image_size, qbits)]
    in_ch, hw = stem_ch, image_size
    idx = 0
    for t, c, n, s in cfg:
        ch = max(4, int(round(c * width)))
        for b in range(n):
            stride = s if b == 0 else 1
            blk = _inverted_residual(
                f"ir{idx}", in_ch, ch, stride, t, hw, qbits
            )
            blocks.append(blk)
            in_ch = ch
            hw = -(-hw // stride)
            idx += 1
    head_specs = {
        "head.w": ((in_ch, num_classes), "he"),
        "head.b": ((num_classes,), "zeros"),
    }
    return Arch(
        name="mobilenetv2",
        blocks=blocks,
        head_specs=head_specs,
        head_flops=in_ch * num_classes,
        num_classes=num_classes,
        image_size=image_size,
        feat_ch=in_ch,
    )
