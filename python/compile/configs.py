"""Artifact configurations: which (arch, method, batch) tuples get lowered.

The default set is sized for the single-core CPU testbed (DESIGN.md
§Substitutions): the *structure* of every model in the paper is available
(resnet8..resnet110, mobilenetv2), while the default artifact bundle is
built at reduced width/resolution so `make artifacts` and the end-to-end
experiments complete in CI-scale time.  `aot.py --preset paper` lowers
full-size models.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from . import archs as A


@dataclasses.dataclass(frozen=True)
class ArchCfg:
    """One lowering target: model family + scale + data shape."""

    name: str  # artifact family name, e.g. "resnet8-c10-tiny"
    arch: str  # resnet | mobilenetv2
    depth_n: int  # resnet: blocks per stage (6n+2); mbv2: ignored
    num_classes: int
    image_size: int
    width: float
    batch: int
    eval_batch: int
    mbv2_cfg: Optional[Tuple[Tuple[int, int, int, int], ...]] = None

    def build(self, qbits: Optional[int] = None) -> A.Arch:
        if self.arch == "resnet":
            return A.resnet(
                self.depth_n,
                self.num_classes,
                image_size=self.image_size,
                width=self.width,
                qbits=qbits,
            )
        if self.arch == "mobilenetv2":
            cfg = list(self.mbv2_cfg) if self.mbv2_cfg else None
            return A.mobilenet_v2(
                self.num_classes,
                image_size=self.image_size,
                width=self.width,
                qbits=qbits,
                cfg=cfg,
            )
        raise ValueError(self.arch)


# Reduced MobileNetV2 stack for the CPU testbed (stride plan preserved).
_MBV2_TINY = ((1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 2, 2), (6, 64, 2, 1))

ARCH_CFGS: Dict[str, ArchCfg] = {
    # Default experiment workhorse: every coordinator feature exercised
    # in minutes on one core.  ResNet-8 structure (1 block/stage).
    "resnet8-c10-tiny": ArchCfg(
        "resnet8-c10-tiny", "resnet", 1, 10, 16, 0.5, 32, 128
    ),
    # The ablation model: ResNet-20-class (3 blocks/stage) at 16px —
    # stands in for the paper's ResNet-74 ablations (same 6n+2 family,
    # 9 gateable blocks).
    "resnet20-c10": ArchCfg("resnet20-c10", "resnet", 3, 10, 16, 0.5, 32, 128),
    # CIFAR-100-class variant (Table 1 / Table 4 rows).
    "resnet20-c100": ArchCfg("resnet20-c100", "resnet", 3, 100, 16, 0.5, 32, 128),
    # MobileNetV2 rows of Table 4.
    "mbv2-c10-tiny": ArchCfg(
        "mbv2-c10-tiny", "mobilenetv2", 0, 10, 16, 0.35, 32, 128, _MBV2_TINY
    ),
    # Paper-scale structures (lowered only with --preset paper; the
    # coordinator and energy ledger accept them like any other family).
    "resnet74-c10": ArchCfg("resnet74-c10", "resnet", 12, 10, 32, 1.0, 128, 256),
    "resnet110-c10": ArchCfg("resnet110-c10", "resnet", 18, 10, 32, 1.0, 128, 256),
    "resnet110-c100": ArchCfg("resnet110-c100", "resnet", 18, 100, 32, 1.0, 128, 256),
    "mbv2-c10": ArchCfg("mbv2-c10", "mobilenetv2", 0, 10, 32, 1.0, 128, 256),
}

# Methods lowered per arch family by default.
DEFAULT_METHODS: List[str] = [
    "sgd32",
    "fixed8",
    "signsgd",
    "psg",
    "slu",
    "sd",
    "e2train",
    "headft",
]

PRESETS: Dict[str, List[str]] = {
    # `make artifacts` default: everything the test-suite and the
    # experiment harness need.
    "default": ["resnet8-c10-tiny", "resnet20-c10", "resnet20-c100", "mbv2-c10-tiny"],
    # Minimal bundle for fast iteration.
    "tiny": ["resnet8-c10-tiny"],
    # Full-size structures (hours of lowering; not built by default).
    "paper": ["resnet74-c10", "resnet110-c10", "resnet110-c100", "mbv2-c10"],
}
