"""Layer-2 building blocks: conv / batchnorm / linear / LSTM cell.

Everything is expressed over plain dicts of jnp arrays so the AOT boundary
(rust feeds a flat, manifest-ordered list of buffers) stays trivial.  All
shapes are NHWC / HWIO.

Initializers return *specs* — ``(shape, init_kind)`` tuples — rather than
materialized arrays: the rust coordinator owns parameter state and performs
He/zeros/ones initialization itself (rust/src/optim/init.rs) from the
manifest emitted by aot.py.  Python only materializes params for its own
tests.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Spec = Tuple[Tuple[int, ...], str]  # (shape, init kind: he|zeros|ones|lstm)

BN_MOMENTUM = 0.1
BN_EPS = 1e-5


# --------------------------------------------------------------------------
# Parameter materialization (python-side tests + aot example args only)
# --------------------------------------------------------------------------

def materialize(specs: Dict[str, Spec], seed: int = 0) -> Dict[str, jnp.ndarray]:
    """He/zeros/ones init matching rust/src/optim/init.rs bit-for-bit in
    distribution (not in RNG stream — each side owns its own seed)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, (shape, kind) in specs.items():
        if kind == "he":
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            std = math.sqrt(2.0 / max(fan_in, 1))
            out[name] = jnp.asarray(
                rng.normal(0.0, std, size=shape).astype(np.float32)
            )
        elif kind == "zeros":
            out[name] = jnp.zeros(shape, jnp.float32)
        elif kind == "ones":
            out[name] = jnp.ones(shape, jnp.float32)
        elif kind == "uniform":
            bound = 1.0 / math.sqrt(max(shape[0], 1))
            out[name] = jnp.asarray(
                rng.uniform(-bound, bound, size=shape).astype(np.float32)
            )
        else:
            raise ValueError(f"unknown init kind {kind}")
    return out


# --------------------------------------------------------------------------
# Ops
# --------------------------------------------------------------------------

def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """SAME conv, NHWC x HWIO -> NHWC."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_flops(
    h: int, w: int, kh: int, kw: int, cin: int, cout: int, stride: int
) -> int:
    """MACs of one SAME conv at the given input spatial size."""
    oh, ow = -(-h // stride), -(-w // stride)
    return oh * ow * kh * kw * cin * cout


def bn_train(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """BatchNorm with batch statistics; returns (out, mean, var) so the
    caller can fold the stats into the running EMA outside the VJP."""
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    inv = jax.lax.rsqrt(var + BN_EPS)
    out = (x - mean) * inv * scale + bias
    return out, mean, var


def bn_eval(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    rmean: jnp.ndarray,
    rvar: jnp.ndarray,
) -> jnp.ndarray:
    inv = jax.lax.rsqrt(rvar + BN_EPS)
    return (x - rmean) * inv * scale + bias


def ema(running: jnp.ndarray, batch: jnp.ndarray) -> jnp.ndarray:
    return (1.0 - BN_MOMENTUM) * running + BN_MOMENTUM * batch


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return x @ w + b


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def relu6(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(x, 0.0, 6.0)


def softmax_xent(
    logits: jnp.ndarray, labels: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean cross-entropy + per-batch correct count (f32 scalar).

    Rows with a negative label are padding (the coordinator pads eval
    tail batches with label -1) and contribute exactly zero to both
    metrics; without the mask, negative indices would wrap to the last
    class and charge loss for padded rows.
    """
    logp = jax.nn.log_softmax(logits)
    n = logits.shape[0]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    nll = -logp[jnp.arange(n), safe] * valid.astype(logp.dtype)
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    )
    return jnp.mean(nll), correct


# --------------------------------------------------------------------------
# LSTM cell for the RNNGates (appendix C: single layer, dim 10, shared)
# --------------------------------------------------------------------------

GATE_DIM = 10


def lstm_cell(
    x: jnp.ndarray,
    h: jnp.ndarray,
    c: jnp.ndarray,
    wi: jnp.ndarray,
    wh: jnp.ndarray,
    b: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One LSTM step; gates packed as [i, f, g, o] along the last axis."""
    z = x @ wi + h @ wh + b
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_specs(prefix: str) -> Dict[str, Spec]:
    return {
        f"{prefix}.wi": ((GATE_DIM, 4 * GATE_DIM), "uniform"),
        f"{prefix}.wh": ((GATE_DIM, 4 * GATE_DIM), "uniform"),
        f"{prefix}.b": ((4 * GATE_DIM,), "zeros"),
    }
